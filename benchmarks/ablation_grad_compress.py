"""Beyond-paper ablation: base-√2 log gradient compression.

Three trainings of the same tiny LM on the same data:
  fp32       — uncompressed gradients (reference)
  log-EF     — 7-bit log-quantized gradients WITH error feedback (ours)
  log-naive  — 7-bit quantization WITHOUT error feedback

Claim: EF keeps convergence at fp32 level while moving 7/32 of the bytes;
naive quantization degrades.  (Wire-byte win is modelled in §Roofline —
this table is the convergence side of the trade.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, ShardedLoader
from repro.models.transformer import init_params, lm_loss
from repro.training.grad_compress import (CompressorConfig,
                                          compress_decompress,
                                          compressor_init,
                                          log_compress_gradients,
                                          wire_bytes_fraction)
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import TrainConfig, make_train_step, \
    init_train_state

from .common import fmt_table

STEPS = 60


def _train(mode: str) -> float:
    cfg = get_config("gemma-2b").reduced(n_layers=2, vocab=256, d_model=64,
                                         d_ff=128, head_dim=16)
    params = init_params(cfg, jax.random.PRNGKey(0))
    loss_fn = lambda p, b: lm_loss(p, b, cfg, xent_chunk=32)
    tcfg = TrainConfig(opt=OptimizerConfig(lr=5e-3, warmup_steps=5,
                                           total_steps=STEPS,
                                           schedule="constant"),
                       grad_compress=False, log_every=0)
    loader = ShardedLoader(DataConfig(seq_len=32, global_batch=8,
                                      vocab=256, seed=3))
    state = init_train_state(params, tcfg)
    base_step = make_train_step(loss_fn, tcfg)
    ccfg = CompressorConfig()
    comp_state = compressor_init(params, ccfg)

    def step(state, comp_state, batch):
        (loss, _), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(state["params"], batch)
        if mode == "log-EF":
            grads, comp_state = log_compress_gradients(grads, comp_state,
                                                       ccfg)
        elif mode == "log-naive":
            grads = jax.tree.map(
                lambda g: compress_decompress(g.astype(jnp.float32))
                if g.size >= ccfg.min_size else g, grads)
        from repro.training.optimizer import clip_by_global_norm, \
            make_optimizer
        grads, _ = clip_by_global_norm(grads, tcfg.opt.grad_clip)
        _, opt_update = make_optimizer(tcfg.opt)
        new_params, new_opt = opt_update(grads, state["opt"],
                                         state["params"])
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1}, comp_state, loss)

    step = jax.jit(step)
    losses = []
    for s in range(STEPS):
        state, comp_state, loss = step(state, comp_state, loader.batch(s))
        losses.append(float(loss))
    return sum(losses[-10:]) / 10


def run() -> dict:
    final = {m: _train(m) for m in ("fp32", "log-EF", "log-naive")}
    rows = [{"mode": m, "final_loss(10-step avg)": round(v, 4),
             "wire_bytes": "1.00×" if m == "fp32"
             else f"{wire_bytes_fraction():.3f}×"} for m, v in final.items()]
    print(fmt_table(rows, list(rows[0])))
    gap_ef = final["log-EF"] - final["fp32"]
    gap_naive = final["log-naive"] - final["fp32"]
    # claim: compressed training matches fp32 at 0.219× wire bytes.  (At
    # this scale even naive quantization converges — the EF-vs-naive
    # separation is the *bias bound* property, asserted in
    # tests/test_training.py::test_error_feedback_preserves_mean_signal.)
    ok = abs(gap_ef) < 0.15
    print(f"EF gap to fp32: {gap_ef:+.4f} nats (naive: {gap_naive:+.4f}) "
          f"at {wire_bytes_fraction():.3f}× wire bytes: "
          f"{'OK' if ok else 'FAIL'}")
    return {"rows": rows, "ef_gap": gap_ef, "naive_gap": gap_naive,
            "ok": ok}
