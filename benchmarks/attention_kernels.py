"""GQA/MQA attention kernel bench: native kv-head-grid Pallas path vs the
legacy `jnp.repeat` expansion, across serving-shaped (decode / prefill)
cases.

Times the blockwise jnp path (what model lowering uses on CPU) against a
full-softmax reference on small shapes, and runs Pallas interpret-mode
probes — including a **traced-offset decode** probe (q_offset as a jitted
scalar operand, the case that used to fall back to blockwise) — as
correctness checks.  Emits ``BENCH_attention.json`` at the repo root via
`benchmarks/common.py`.

Timing hygiene matches `conv_kernels.py`: jitted entry points hoisted to
module level, compile reported separately from the steady-state mean.

Each row carries the analytic HBM traffic per path
(`kernels/flash_attention.attention_traffic_bytes`).  On CPU the timings
measure interpreter overhead, but the bytes-moved columns are
backend-independent and must show the native GQA path moving ≥2× fewer
bytes than the repeat path on every H/Hkv = 4 case with Tk ≥ 4096 — K/V
traffic scaling with kv heads, not query heads (the paper's broadcast
dataflow argument).
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops
from repro.kernels.autotune import default_attention_config
from repro.kernels.flash_attention import attention_traffic_bytes
from repro.kernels.ref import ref_attention

from .common import fmt_table, write_json

TRAFFIC_WIN_GQA4 = 2.0   # acceptance: native ≥2× fewer bytes at rep=4

# (case, B, Tq, Tk, H, Hkv, D) — decode/prefill shapes at serving ratios
CASES = [
    ("decode_gqa4",   1,   1, 4096,  8, 2, 64),
    ("decode_gqa4_8k", 1,  1, 8192,  8, 2, 64),
    ("decode_mqa",    1,   1, 4096,  8, 1, 64),
    ("prefill_gqa4",  1, 128, 4096,  8, 2, 64),
    ("decode_mha",    1,   1, 4096,  8, 8, 64),   # control: no GQA win
]


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def _attn(q, k, v, *, impl, interpret=None):
    return ops.attention(q, k, v, causal=True, impl=impl,
                         interpret=interpret)


@jax.jit
def _attn_decode_traced(q, k, v, q_offset):
    # q_offset is a traced scalar: exercises the scalar-prefetch decode
    # path of the Pallas kernel (previously a blockwise fallback).
    return ops.attention(q, k, v, causal=True, q_offset=q_offset,
                         impl="pallas", interpret=True)


def _bench(fn, *args, reps: int = 5, **kw):
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args, **kw))
    compile_us = (time.perf_counter() - t0) * 1e6
    jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return compile_us, (time.perf_counter() - t0) / reps * 1e6


def run() -> dict:
    rng = np.random.default_rng(0)
    rows, ok = [], True
    for case, B, Tq, Tk, H, Hkv, D in CASES:
        q = jnp.asarray(rng.normal(size=(B, Tq, H, D)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, Tk, Hkv, D)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, Tk, Hkv, D)), jnp.float32)
        bw_c, bw_us = _bench(_attn, q, k, v, impl="blockwise")

        blocks = default_attention_config(B, Tq, Tk, H, Hkv, D)
        traffic = {p: attention_traffic_bytes(p, B, Tq, Tk, H, Hkv, D,
                                              **blocks)
                   for p in ("pallas", "repeat", "blockwise")}
        # the claim under test is the K/V term: the repeat path moves K/V
        # proportional to H query heads, the native kernel to Hkv kv heads
        win = traffic["repeat"]["kv"] / traffic["pallas"]["kv"]
        rep = H // Hkv
        traffic_ok = (win >= TRAFFIC_WIN_GQA4) \
            if (rep >= 4 and Tk >= 4096) else True
        ok &= traffic_ok
        rows.append({
            "case": case, "shape": f"{B}x{Tq}/{Tk}x{H}.{Hkv}x{D}",
            "rep": rep,
            "blockwise_us": round(bw_us, 1),
            "blockwise_compile_us": round(bw_c, 1),
            "bytes_repeat": traffic["repeat"]["total"],
            "bytes_native": traffic["pallas"]["total"],
            "bytes_blockwise": traffic["blockwise"]["total"],
            "kv_bytes_repeat": traffic["repeat"]["kv"],
            "kv_bytes_native": traffic["pallas"]["kv"],
            "native_traffic_win_x": round(win, 2),
            "ok": traffic_ok,
        })

    # Pallas interpret probes (correctness, not speed): native GQA kernel
    # ≡ blockwise ≡ ref on a small GQA shape, plus traced-offset decode.
    B, T, H, Hkv, D = 1, 64, 8, 2, 16
    q = jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, Hkv, D)), jnp.float32)
    want = ref_attention(q, k, v, causal=True)
    c_us, s_us = _bench(_attn, q, k, v, impl="pallas", interpret=True,
                        reps=3)
    d_full = float(jnp.max(jnp.abs(
        _attn(q, k, v, impl="pallas", interpret=True) - want)))
    dec = _attn_decode_traced(q[:, -1:], k, v, jnp.asarray(T - 1, jnp.int32))
    d_dec = float(jnp.max(jnp.abs(dec[:, 0] - want[:, -1])))
    probes = {"pallas_gqa": {"compile_us": round(c_us, 1),
                             "steady_us": round(s_us, 1), "maxdiff": d_full},
              "pallas_decode_traced_offset": {"maxdiff": d_dec}}
    probes_ok = d_full < 1e-3 and d_dec < 1e-3
    ok &= probes_ok

    cols = ["case", "shape", "rep", "blockwise_us", "bytes_repeat",
            "bytes_native", "native_traffic_win_x", "ok"]
    print(fmt_table(rows, cols))
    for name, p in probes.items():
        print(f"{name}(interpret) probe: |Δ vs ref| = {p['maxdiff']:.2e} "
              f"({'OK' if p['maxdiff'] < 1e-3 else 'FAIL'})")
    min_win = min(r["native_traffic_win_x"] for r in rows if r["rep"] >= 4)
    out = {"rows": rows, "probes": probes,
           "pallas_interpret_maxdiff": max(p["maxdiff"]
                                           for p in probes.values()),
           "min_gqa4_traffic_win_x": min_win, "ok": ok}
    path = write_json("BENCH_attention.json", out)
    print(f"wrote {path}")
    return out
