"""Shared benchmark plumbing: every paper table/figure is a module with
``run() -> dict`` (printable rows + derived headline numbers)."""

from __future__ import annotations

import json
import os
import time


def timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6  # µs


def write_json(filename: str, payload: dict) -> str:
    """Persist a benchmark's result dict (e.g. ``BENCH_conv.json``) at the
    repo root so runs are diffable across PRs.  When a previous run exists,
    prints a per-row timing delta table (flagging >1.3× slowdowns) before
    overwriting.  Returns the path written."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, filename)
    prev = None
    if os.path.exists(path):
        try:
            with open(path) as f:
                prev = json.load(f)
        except (OSError, ValueError):
            prev = None
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True, default=str)
        f.write("\n")
    if prev is not None:
        report = regression_report(prev, payload, name=filename)
        if report:
            print(report)
    return path


SLOWDOWN_FLAG_X = 1.3

_ID_FIELDS = ("net", "layer", "name", "case", "shape")


def _row_id(row: dict) -> tuple:
    return tuple(str(row[k]) for k in _ID_FIELDS if k in row)


def regression_report(prev: dict, new: dict, *, name: str = "",
                      threshold: float = SLOWDOWN_FLAG_X) -> str:
    """Per-row delta table between two benchmark payloads.

    Matches ``rows`` entries by their identity fields and compares every
    ``*_us`` timing column; ratios above ``threshold`` are flagged so a
    perf regression is visible right in the benchmark output.  Returns ""
    when there is nothing comparable."""
    prev_rows = {_row_id(r): r for r in prev.get("rows", [])
                 if isinstance(r, dict)}
    deltas, flagged = [], 0
    for row in new.get("rows", []):
        if not isinstance(row, dict):
            continue
        old = prev_rows.get(_row_id(row))
        if old is None:
            continue
        for col, val in row.items():
            if not col.endswith("_us") or not isinstance(val, (int, float)):
                continue
            was = old.get(col)
            if not isinstance(was, (int, float)) or was <= 0:
                continue
            ratio = val / was
            flag = f"SLOW>{threshold}x" if ratio > threshold else ""
            flagged += bool(flag)
            deltas.append({"row": ":".join(_row_id(row)) or "-", "col": col,
                           "prev_us": round(was, 1), "now_us": round(val, 1),
                           "ratio_x": round(ratio, 2), "flag": flag})
    if not deltas:
        return ""
    head = f"Δ vs previous {name or 'run'}".rstrip()
    tail = (f"{flagged} column(s) regressed more than {threshold}x"
            if flagged else "no timing regressions above threshold")
    return "\n".join([head, fmt_table(
        deltas, ["row", "col", "prev_us", "now_us", "ratio_x", "flag"]),
        tail])


def fmt_table(rows: list[dict], cols: list[str]) -> str:
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    line = " | ".join(c.ljust(widths[c]) for c in cols)
    sep = "-+-".join("-" * widths[c] for c in cols)
    body = "\n".join(" | ".join(str(r.get(c, "")).ljust(widths[c])
                                for c in cols) for r in rows)
    return f"{line}\n{sep}\n{body}"
