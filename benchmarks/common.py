"""Shared benchmark plumbing: every paper table/figure is a module with
``run() -> dict`` (printable rows + derived headline numbers)."""

from __future__ import annotations

import time


def timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6  # µs


def fmt_table(rows: list[dict], cols: list[str]) -> str:
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    line = " | ".join(c.ljust(widths[c]) for c in cols)
    sep = "-+-".join("-" * widths[c] for c in cols)
    body = "\n".join(" | ".join(str(r.get(c, "")).ljust(widths[c])
                                for c in cols) for r in rows)
    return f"{line}\n{sep}\n{body}"
