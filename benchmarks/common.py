"""Shared benchmark plumbing: every paper table/figure is a module with
``run() -> dict`` (printable rows + derived headline numbers)."""

from __future__ import annotations

import json
import os
import time


def timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6  # µs


def write_json(filename: str, payload: dict) -> str:
    """Persist a benchmark's result dict (e.g. ``BENCH_conv.json``) at the
    repo root so runs are diffable across PRs.  Returns the path written."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, filename)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True, default=str)
        f.write("\n")
    return path


def fmt_table(rows: list[dict], cols: list[str]) -> str:
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows))
              for c in cols}
    line = " | ".join(c.ljust(widths[c]) for c in cols)
    sep = "-+-".join("-" * widths[c] for c in cols)
    body = "\n".join(" | ".join(str(r.get(c, "")).ljust(widths[c])
                                for c in cols) for r in rows)
    return f"{line}\n{sep}\n{body}"
