"""Log-conv kernel timings across the paper's CNN layer shapes.

Times `kernels/ops.conv2d` (blockwise jnp path, plus the Pallas kernel in
interpret mode on the smallest layer as a correctness probe) against the
fp32 `lax.conv` baseline, on VGG-16 / MobileNet-v1 layer shapes from
`core/accelerator.py` scaled to a CI-sized image.  Emits ``BENCH_conv.json``
at the repo root via `benchmarks/common.py`.

On CPU the headline number is *overhead* of the decode-fused path vs fp32
(interpret-mode Pallas is not a perf proxy); on TPU the same dispatch hits
the MXU kernel where weight bytes moved drop 4× vs f32.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accelerator import mobilenet_v1_layers, vgg16_layers
from repro.core.logquant import quantize_tensor
from repro.kernels import ops

from .common import fmt_table, write_json

IMG = 32  # CI-sized spatial scale for the paper's 224px layer stacks


def _bench(fn, *args, reps: int = 3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # µs


def _layer_cases():
    vgg = {l.name: l for l in vgg16_layers(IMG)}
    mbn = {l.name: l for l in mobilenet_v1_layers(IMG)}
    picks = [("vgg16", vgg["CONV1_1"]), ("vgg16", vgg["CONV3_1"]),
             ("mobilenet_v1", mbn["DW2"]), ("mobilenet_v1", mbn["PW2"])]
    for net, spec in picks:
        groups = spec.C if spec.kind == "dwconv" else 1
        yield net, spec, groups


def run() -> dict:
    rng = np.random.default_rng(0)
    rows, ok = [], True
    for net, spec, groups in _layer_cases():
        H = W = spec.H
        x = jnp.asarray(rng.normal(size=(1, H, W, spec.C))
                        .astype(np.float32))
        w = jnp.asarray(rng.normal(
            size=(spec.K, spec.K, spec.C // groups, spec.P))
            .astype(np.float32))
        qt = quantize_tensor(w)
        kw = dict(stride=spec.stride, padding=spec.pad, groups=groups)

        base = jax.jit(lambda x, w: jax.lax.conv_general_dilated(
            x, w, (spec.stride, spec.stride),
            [(spec.pad, spec.pad)] * 2 if isinstance(spec.pad, int)
            else spec.pad,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=groups))
        bw = jax.jit(lambda x: ops.conv2d(x, qt, impl="blockwise", **kw))

        us_fp = _bench(base, x, w)
        us_bw = _bench(bw, x)
        y_fp, y_bw = base(x, w), bw(x)
        # quant error envelope, not a bitwise check: ~|w|·√2-halfstep
        rel = float(jnp.linalg.norm(y_bw - y_fp) /
                    (jnp.linalg.norm(y_fp) + 1e-9))
        row_ok = rel < 0.2 and y_bw.shape == y_fp.shape
        ok &= row_ok
        rows.append({
            "net": net, "layer": spec.name,
            "shape": f"{H}x{W}x{spec.C}->{spec.P}",
            "K": spec.K, "stride": spec.stride, "groups": groups,
            "fp32_us": round(us_fp, 1), "logq_blockwise_us": round(us_bw, 1),
            "overhead_x": round(us_bw / max(us_fp, 1e-9), 2),
            "rel_quant_err": round(rel, 4), "ok": row_ok,
        })

    # Pallas interpret probe on the smallest layer (correctness, not speed)
    net, spec, groups = next(iter(_layer_cases()))
    x = jnp.asarray(rng.normal(size=(1, 8, 8, spec.C)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 3, spec.C, 16))
                    .astype(np.float32))
    qt = quantize_tensor(w)
    us_pl = _bench(lambda: ops.conv2d(x, qt, impl="pallas", interpret=True),
                   reps=1)
    d = float(jnp.max(jnp.abs(
        ops.conv2d(x, qt, impl="pallas", interpret=True) -
        ops.conv2d(x, qt, impl="blockwise"))))
    pallas_ok = d < 1e-3
    ok &= pallas_ok

    print(fmt_table(rows, list(rows[0])))
    print(f"pallas(interpret) probe: {us_pl:.0f} µs, "
          f"|pallas - blockwise| = {d:.2e} "
          f"({'OK' if pallas_ok else 'FAIL'})")
    mean_over = float(np.mean([r["overhead_x"] for r in rows]))
    out = {"rows": rows, "pallas_interpret_maxdiff": d,
           "mean_blockwise_overhead_x": mean_over, "img": IMG, "ok": ok}
    path = write_json("BENCH_conv.json", out)
    print(f"wrote {path}")
    return out
