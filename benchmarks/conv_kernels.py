"""Log-conv kernel timings across the paper's CNN layer shapes.

Times `kernels/ops.conv2d` (blockwise jnp path, plus fused and im2col
Pallas probes in interpret mode on a small layer as correctness checks)
against the fp32 `lax.conv` baseline, on VGG-16 / MobileNet-v1 layer
shapes from `core/accelerator.py` scaled to a CI-sized image.  Emits
``BENCH_conv.json`` at the repo root via `benchmarks/common.py` (which
also prints a delta table against the previous run).

Timing hygiene: the jitted entry points are hoisted to module level (one
`jax.jit` per function, shapes retrace but calls hit the jit cache — no
per-layer lambda re-tracing), and the first call (compile) is reported
separately from the steady-state mean.

Each row also carries the analytic HBM traffic per impl
(`kernels/log_conv2d.conv_traffic_bytes`): packed int8 codes vs
materialized patches vs fp32, and the fused/im2col activation+weight
ratio — on CPU the timings measure decode overhead, but the bytes-moved
columns are backend-independent and must show the fused kernel winning
≥4× on every 3×3 layer.

A second table covers the lane-packed grouped/depthwise layout
(MobileNet-style ``cin_g ∈ {1, 2, 4}``): analytic bytes at the physical
128-lane width, auto-packed vs forced-padded, gated at ≥4× recovery for
every narrow-group shape.

A third, ``cold_start``, section gates the autotune warm-start tier: a
fresh process (empty user cache) tracing quantized inference over all
four paper CNNs at 224 px must resolve **every** conv dispatch from the
packaged table — zero tuning sweeps, zero heuristic fallbacks
(`autotune_lookup` counters: `hit_warm` == dispatches, `miss` == 0).
"""

from __future__ import annotations

import functools
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.neuromax_cnn import CONFIG as CNN_CONFIG
from repro.core.accelerator import mobilenet_v1_layers, vgg16_layers
from repro.core.logquant import quantize_tensor
from repro.kernels import autotune, ops
from repro.kernels.log_conv2d import conv_traffic_bytes
from repro.models import cnn as cnn_models
from repro.obs import metrics as obs_metrics
from repro.serving.quantize import quantize_cnn_params

from .common import fmt_table, write_json

IMG = 32    # CI-sized spatial scale for the paper's 224px layer stacks
BATCH = 4   # serving-sized microbatch: traffic ratios reflect deployment
TRAFFIC_WIN_3X3 = 4.0  # acceptance: fused moves ≥4× fewer act+w bytes
LANE_PACK_WIN = 4.0    # acceptance: lane-packed ≥4× fewer 128-lane bytes


@functools.partial(jax.jit, static_argnames=("stride", "pads", "groups"))
def _fp32_conv(x, w, *, stride, pads, groups):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), pads,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


@functools.partial(jax.jit, static_argnames=("impl", "stride", "padding",
                                             "groups", "interpret"))
def _logq_conv(x, qt, *, impl, stride, padding, groups, interpret=None):
    return ops.conv2d(x, qt, impl=impl, stride=stride, padding=padding,
                      groups=groups, interpret=interpret)


def _bench(fn, *args, reps: int = 5, **kw):
    """→ (compile_us, steady_us): first call times compile+run, then the
    steady-state mean over ``reps`` after a warm-up call."""
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args, **kw))
    compile_us = (time.perf_counter() - t0) * 1e6
    jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return compile_us, (time.perf_counter() - t0) / reps * 1e6


def _pads_for(spec):
    if isinstance(spec.pad, int):
        return ((spec.pad, spec.pad), (spec.pad, spec.pad))
    return spec.pad


def _layer_cases():
    vgg = {l.name: l for l in vgg16_layers(IMG)}
    mbn = {l.name: l for l in mobilenet_v1_layers(IMG)}
    picks = [("vgg16", vgg["CONV1_1"]), ("vgg16", vgg["CONV3_1"]),
             ("mobilenet_v1", mbn["DW2"]), ("mobilenet_v1", mbn["PW2"])]
    for net, spec in picks:
        groups = spec.C if spec.kind == "dwconv" else 1
        yield net, spec, groups


def _autotune_counts() -> dict:
    """Current `autotune_lookup`/`autotune_sweep` totals (conv2d op)."""
    out = {"hit_user": 0, "hit_warm": 0, "miss": 0, "sweeps": 0}
    for name, v in obs_metrics.REGISTRY.snapshot()["counters"].items():
        if name.startswith("autotune_sweep"):
            out["sweeps"] += v
        elif name.startswith("autotune_lookup") and 'op="conv2d"' in name:
            for r in ("hit_user", "hit_warm", "miss"):
                if f'result="{r}"' in name:
                    out[r] += v
    return out


def cold_start_section(img: int = 224, batch: int = 1) -> dict:
    """First-inference warm-start gate: with an **empty user cache** (the
    env tier pointed at a file that doesn't exist), shape-trace quantized
    inference over the four paper CNNs exactly as serving dispatches it
    (packed `QuantizedTensor` weights, ``conv_impl="pallas"``, lane-packed
    depthwise layout) and require every conv dispatch to resolve from the
    packaged warm-start tier.  `jax.eval_shape` runs the real dispatch
    path — config resolution and table lookups happen at trace time — so
    the gate covers the full 224 px layer stacks in seconds."""
    prev = os.environ.get("REPRO_AUTOTUNE_PATH")
    os.environ["REPRO_AUTOTUNE_PATH"] = os.path.join(
        tempfile.mkdtemp(prefix="repro-coldstart-"), "empty.json")
    autotune.reset_cache()
    per_net, before = {}, _autotune_counts()
    try:
        for name in cnn_models.CNN_ZOO:
            init, apply = cnn_models.CNN_ZOO[name]

            def run_net(key, x, init=init, apply=apply):
                qp = quantize_cnn_params(init(key), CNN_CONFIG.qcfg,
                                         conv_layout="lane_packed")
                return apply(qp, x, conv_impl="pallas")

            n0 = _autotune_counts()
            jax.eval_shape(run_net, jax.ShapeDtypeStruct((2,), jnp.uint32),
                           jax.ShapeDtypeStruct((batch, img, img, 3),
                                                jnp.float32))
            n1 = _autotune_counts()
            per_net[name] = {k: n1[k] - n0[k] for k in n0}
    finally:
        if prev is None:
            os.environ.pop("REPRO_AUTOTUNE_PATH", None)
        else:
            os.environ["REPRO_AUTOTUNE_PATH"] = prev
        autotune.reset_cache()
    after = _autotune_counts()
    d = {k: after[k] - before[k] for k in before}
    dispatches = d["hit_user"] + d["hit_warm"] + d["miss"]
    ok = (dispatches > 0 and d["miss"] == 0 and d["sweeps"] == 0
          and d["hit_warm"] == dispatches)
    return {"img": img, "batch": batch, "conv_dispatches": dispatches,
            "hit_warm": d["hit_warm"], "hit_user": d["hit_user"],
            "miss": d["miss"], "sweeps": d["sweeps"],
            "per_net": per_net, "ok": ok}


def run() -> dict:
    rng = np.random.default_rng(0)
    rows, ok = [], True
    for net, spec, groups in _layer_cases():
        H = W = spec.H
        x = jnp.asarray(rng.normal(size=(BATCH, H, W, spec.C))
                        .astype(np.float32))
        w = jnp.asarray(rng.normal(
            size=(spec.K, spec.K, spec.C // groups, spec.P))
            .astype(np.float32))
        qt = quantize_tensor(w)
        shape_kw = dict(stride=spec.stride, padding=spec.pad, groups=groups)

        fp_c, fp_us = _bench(_fp32_conv, x, w, stride=spec.stride,
                             pads=_pads_for(spec), groups=groups)
        bw_c, bw_us = _bench(_logq_conv, x, qt, impl="blockwise", **shape_kw)
        y_fp = _fp32_conv(x, w, stride=spec.stride, pads=_pads_for(spec),
                          groups=groups)
        y_bw = _logq_conv(x, qt, impl="blockwise", **shape_kw)
        # quant error envelope, not a bitwise check: ~|w|·√2-halfstep
        rel = float(jnp.linalg.norm(y_bw - y_fp) /
                    (jnp.linalg.norm(y_fp) + 1e-9))

        tkw = dict(B=BATCH, H=H, W=W, C=spec.C, K=spec.K, Cout=spec.P)
        traffic = {impl: conv_traffic_bytes(impl, **tkw, **shape_kw)
                   for impl in ("fp32", "blockwise", "pallas_im2col",
                                "pallas")}
        win = traffic["pallas_im2col"]["act_w"] / traffic["pallas"]["act_w"]
        traffic_ok = (win >= TRAFFIC_WIN_3X3) if spec.K == 3 else True
        row_ok = rel < 0.2 and y_bw.shape == y_fp.shape and traffic_ok
        ok &= row_ok
        rows.append({
            "net": net, "layer": spec.name,
            "shape": f"{BATCH}x{H}x{W}x{spec.C}->{spec.P}",
            "K": spec.K, "stride": spec.stride, "groups": groups,
            "fp32_us": round(fp_us, 1), "fp32_compile_us": round(fp_c, 1),
            "logq_blockwise_us": round(bw_us, 1),
            "logq_compile_us": round(bw_c, 1),
            "overhead_x": round(bw_us / max(fp_us, 1e-9), 2),
            "rel_quant_err": round(rel, 4),
            "bytes_fp32": traffic["fp32"]["act_w"],
            "bytes_blockwise": traffic["blockwise"]["act_w"],
            "bytes_im2col": traffic["pallas_im2col"]["act_w"],
            "bytes_fused": traffic["pallas"]["act_w"],
            "fused_traffic_win_x": round(win, 2),
            "ok": row_ok,
        })

    # Pallas interpret probes on a small layer (correctness, not speed):
    # fused ≡ im2col ≡ blockwise, compile and steady time reported apart
    x = jnp.asarray(rng.normal(size=(1, 8, 8, 3)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 3, 3, 16)).astype(np.float32))
    qt = quantize_tensor(w)
    pkw = dict(stride=1, padding="SAME", groups=1, interpret=True)
    probes = {}
    y_bw = _logq_conv(x, qt, impl="blockwise", stride=1, padding="SAME",
                      groups=1)
    pallas_ok = True
    for impl in ("pallas", "pallas_im2col"):
        c_us, s_us = _bench(_logq_conv, x, qt, impl=impl, reps=3, **pkw)
        d = float(jnp.max(jnp.abs(_logq_conv(x, qt, impl=impl, **pkw)
                                  - y_bw)))
        probes[impl] = {"compile_us": round(c_us, 1),
                        "steady_us": round(s_us, 1), "maxdiff": d}
        pallas_ok &= d < 1e-3
    ok &= pallas_ok

    # Lane-packed grouped/depthwise section (MobileNet-style narrow
    # groups, cin_g ∈ {1, 2, 4}): analytic HBM bytes at the physical
    # 128-lane width, auto-packed (`lane_pack=None`) vs forced-padded
    # (`lane_pack=1`), plus an interpret-mode correctness probe.  The
    # timing columns above measure CPU decode; these columns are the
    # hardware-honest traffic the packed layout recovers.
    lane_rows, lane_ok = [], True
    lane_cases = [  # (name, C, groups, Cout, K, stride) — cin_g = C//groups
        ("dw_cin1", 64, 64, 64, 3, 1),
        ("dw_cin1_s2", 64, 64, 64, 3, 2),
        ("grp_cin2", 64, 32, 64, 3, 1),
        ("grp_cin4", 64, 16, 64, 3, 1),
    ]
    for name, C, G, Cout, K, stridelp in lane_cases:
        xg = jnp.asarray(rng.normal(size=(1, 8, 8, C)).astype(np.float32))
        wg = jnp.asarray(rng.normal(size=(K, K, C // G, Cout))
                         .astype(np.float32))
        qtg = quantize_tensor(wg)
        gkw = dict(stride=stridelp, padding="SAME", groups=G)
        tkw = dict(B=BATCH, H=IMG, W=IMG, C=C, K=K, Cout=Cout, **gkw)
        packed = conv_traffic_bytes("pallas", lanes=128,
                                    config=dict(lane_pack=None), **tkw)
        padded = conv_traffic_bytes("pallas", lanes=128,
                                    config=dict(lane_pack=1), **tkw)
        win = padded["act_w"] / packed["act_w"]
        y_ref = _logq_conv(xg, qtg, impl="blockwise", **gkw)
        d = float(jnp.max(jnp.abs(
            _logq_conv(xg, qtg, impl="pallas", interpret=True, **gkw)
            - y_ref)))
        cin_g = C // G
        row_ok = (d < 1e-3) and (win >= LANE_PACK_WIN if cin_g <= 4
                                 else True)
        lane_ok &= row_ok
        lane_rows.append({
            "case": name, "cin_g": cin_g, "groups": G, "K": K,
            "stride": stridelp,
            "bytes_padded_128": padded["act_w"],
            "bytes_packed_128": packed["act_w"],
            "lane_pack_win_x": round(win, 2),
            "lane_density_padded": padded["lane_density"],
            "lane_density_packed": packed["lane_density"],
            "maxdiff_vs_blockwise": d, "ok": row_ok,
        })
    ok &= lane_ok

    # Cold-start warm-table gate (ROADMAP "autotune table warm-start"):
    # fresh process ⇒ every conv dispatch of the four CNNs is hit_warm.
    cold = cold_start_section()
    ok &= cold["ok"]

    cols = ["net", "layer", "shape", "K", "stride", "groups", "fp32_us",
            "logq_blockwise_us", "overhead_x", "rel_quant_err",
            "bytes_im2col", "bytes_fused", "fused_traffic_win_x", "ok"]
    print(fmt_table(rows, cols))
    print(fmt_table(lane_rows, ["case", "cin_g", "groups", "K", "stride",
                                "bytes_padded_128", "bytes_packed_128",
                                "lane_pack_win_x", "lane_density_packed",
                                "ok"]))
    for impl, p in probes.items():
        print(f"{impl}(interpret) probe: compile {p['compile_us']:.0f} µs, "
              f"steady {p['steady_us']:.0f} µs, |Δ vs blockwise| = "
              f"{p['maxdiff']:.2e} ({'OK' if p['maxdiff'] < 1e-3 else 'FAIL'})")
    print(f"cold_start: {cold['conv_dispatches']} conv dispatches over "
          f"{list(cold['per_net'])} @ {cold['img']}px — hit_warm "
          f"{cold['hit_warm']}, hit_user {cold['hit_user']}, miss "
          f"{cold['miss']}, sweeps {cold['sweeps']} "
          f"({'OK' if cold['ok'] else 'FAIL'})")
    mean_over = float(np.mean([r["overhead_x"] for r in rows]))
    min_win = min(r["fused_traffic_win_x"] for r in rows if r["K"] == 3)
    out = {"rows": rows, "probes": probes, "lane_rows": lane_rows,
           "cold_start": cold,
           "pallas_interpret_maxdiff": max(p["maxdiff"]
                                           for p in probes.values()),
           "mean_blockwise_overhead_x": mean_over,
           "min_3x3_fused_traffic_win_x": min_win,
           "min_lane_pack_win_x": min(r["lane_pack_win_x"]
                                      for r in lane_rows),
           "img": IMG, "batch": BATCH, "ok": ok}
    path = write_json("BENCH_conv.json", out)
    print(f"wrote {path}")
    return out
