"""Fig. 17 — linear vs log PE LUT/FF cost at 16-bit output precision, and
the headline "200 % more peak throughput for 6 % area" claim."""

from __future__ import annotations

from repro.core.cost_model import (COST_ADJUST_RATIO, LINEAR_PE_FF,
                                   LINEAR_PE_LUT, area_overhead_vs_linear,
                                   cost_adjusted_pe_count, linear_pe_cost,
                                   log_pe_cost, peak_throughput_per_pe)

from .common import fmt_table


def run() -> dict:
    rows = []
    lin = linear_pe_cost()
    for threads in (1, 2, 3, 4):
        c = log_pe_cost(threads)
        rows.append({
            "PE": f"log({threads})",
            "LUTs_rel": round(c.luts / lin.luts, 3),
            "FFs_rel": round(c.ffs / lin.ffs, 3),
            "peak_OPS/cycle": threads,
        })
    rows.append({"PE": "linear", "LUTs_rel": 1.0, "FFs_rel": 1.0,
                 "peak_OPS/cycle": 1})
    print(fmt_table(rows, list(rows[0])))

    overhead = area_overhead_vs_linear(3)
    adj = cost_adjusted_pe_count()
    tput = peak_throughput_per_pe()
    print(f"3-thread log PE: area overhead {overhead*100:.1f}% "
          f"(paper: ≈6%), peak throughput/PE (adjusted) {tput:.2f} "
          f"(paper: 2.7), 108 PEs ≡ {adj} cost-adjusted (paper: 122)")
    ok = abs(overhead - 0.06) < 0.05 and adj == 122 and 2.5 < tput < 3.0
    print("paper claims:", "REPRODUCED" if ok else "FAIL")
    return {"rows": rows, "area_overhead": overhead,
            "adjusted_pes": adj, "tput_per_pe": tput, "ok": ok}
