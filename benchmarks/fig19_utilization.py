"""Fig. 19 — layer-by-layer hardware (thread) utilization for VGG-16,
MobileNet v1 and ResNet-34 on the 6×3×6 grid + 2D weight-broadcast
dataflow.  Paper averages: 95 % / 84 % / 86 %."""

from __future__ import annotations

from repro.core.accelerator import run_network

from .common import fmt_table

PAPER_AVG = {"vgg16": 0.95, "mobilenet_v1": 0.84, "resnet34": 0.86}


def run() -> dict:
    summary = []
    per_layer = {}
    for net, paper in PAPER_AVG.items():
        perf = run_network(net)
        util = perf.mean_layer_utilization
        summary.append({"network": net, "layers": len(perf.layers),
                        "mean_util_%": round(util * 100, 1),
                        "paper_%": paper * 100,
                        "delta_pp": round((util - paper) * 100, 1)})
        per_layer[net] = [round(lp.utilization * 100, 1)
                          for lp in perf.layers]
    print(fmt_table(summary, list(summary[0])))
    print("VGG16 per-layer util %:", per_layer["vgg16"])
    # first VGG16 layer: paper says exactly 50% (3 of 6 PE matrices idle)
    first = per_layer["vgg16"][0]
    ok = all(abs(r["delta_pp"]) <= 2.5 for r in summary) and first <= 51.0
    print("paper claims (±2.5 pp, conv1_1 ≈ 50%):",
          "REPRODUCED" if ok else "FAIL")
    return {"rows": summary, "per_layer": per_layer, "ok": ok}
