"""Fig. 1 — linear vs log quantization quality.

The paper shows weight histograms for VGG16/SqueezeNet under 1.5-bit
linear, 5.0-bit log (base 2) and 5.1-bit log (base √2), and reports VGG16
top-1 dropping ≈3.5 pts under base-√2 vs ≈10 pts under base-2.

No pretrained ImageNet weights exist offline, so we reproduce the claim in
two forms (trend, not absolute top-1 — DESIGN.md §Known deviations):
  1. quantization SNR of realistic (normal, heavy-tailed) weight tensors
     under the three schemes;
  2. logit fidelity of a real (random-init) VGG16 forward pass under
     fake-quant: base-√2 must sit far closer to fp32 than base-2 / low-bit
     linear.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.logquant import (LogQuantConfig, linear_quantize,
                                 log_dequantize, log_quantize,
                                 quantization_snr_db)
from repro.models.cnn import make_cnn

from .common import fmt_table

SCHEMES = {
    "linear Q1.2 (1.5b eff)": ("linear", dict(int_bits=2, frac_bits=2)),
    "log base-2  (5.0b)": ("log", LogQuantConfig(frac_bits=0,
                                                 per_channel=False)),
    "log base-√2 (5.1b)": ("log", LogQuantConfig(frac_bits=1,
                                                 per_channel=False)),
}


def _quantize(w, scheme):
    kind, cfg = scheme
    if kind == "linear":
        scale = float(np.abs(w).max()) or 1.0
        q = linear_quantize(jnp.asarray(w / scale), **cfg)
        return np.asarray(q) * scale
    packed, s = log_quantize(jnp.asarray(w), cfg)
    return np.asarray(log_dequantize(packed, s, cfg))


def run() -> dict:
    rng = np.random.default_rng(0)
    rows = []
    # 1 — SNR on weight-like tensors
    dists = {
        "normal*0.05": rng.normal(size=65536).astype(np.float32) * 0.05,
        "laplace": rng.laplace(size=65536).astype(np.float32) * 0.03,
    }
    snr = {}
    for name, scheme in SCHEMES.items():
        row = {"scheme": name}
        for dname, w in dists.items():
            row[f"snr_{dname}_db"] = round(
                float(quantization_snr_db(w, _quantize(w, scheme))), 2)
        snr[name] = row[f"snr_normal*0.05_db"]
        rows.append(row)

    # 2 — logit fidelity through a real VGG16 forward
    key = jax.random.PRNGKey(1)
    params, apply_fp = make_cnn("vgg16", key, n_classes=100, width_mult=0.25)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 32, 32, 3))
    ref = np.asarray(apply_fp(params, x)).ravel()

    fidelity = {}
    for name, scheme in SCHEMES.items():
        qparams = jax.tree.map(
            lambda w: jnp.asarray(_quantize(np.asarray(w), scheme))
            if w.ndim >= 2 else w, params)
        out = np.asarray(apply_fp(qparams, x)).ravel()
        fidelity[name] = float(np.corrcoef(ref, out)[0, 1])

    for row in rows:
        row["vgg16_logit_corr"] = round(fidelity[row["scheme"]], 4)

    print(fmt_table(rows, list(rows[0])))
    s2, ss2 = snr["log base-2  (5.0b)"], snr["log base-√2 (5.1b)"]
    ok = ss2 > s2 + 4.0 and \
        fidelity["log base-√2 (5.1b)"] > fidelity["log base-2  (5.0b)"]
    print(f"paper claim (base-√2 ≫ base-2): {'REPRODUCED' if ok else 'FAIL'}"
          f"  (ΔSNR={ss2-s2:+.1f} dB)")
    return {"rows": rows, "snr_gain_db": ss2 - s2,
            "corr_sqrt2": fidelity["log base-√2 (5.1b)"], "ok": ok}
