"""Fig. 20 — PE count vs utilization vs throughput against VWA [15].

Paper: NeuroMAX at 122 cost-adjusted PEs delivers 307.8 / 281.8 / 268.92
GOPS for VGG16 / ResNet-34 / MobileNet (85 / 79.4 / 77.4 % more than [15]
at 168 PEs), with similar utilization."""

from __future__ import annotations

from repro.core.accelerator import run_network
from repro.core.cost_model import cost_adjusted_pe_count

from .common import fmt_table

# [15] (VWA, Chang & Chang 2020) figures quoted by the paper, at 200 MHz
VWA = {"vgg16": (0.99, 166.32), "resnet34": (0.934, 156.91),
       "mobilenet_v1": (0.902, 151.54)}
PAPER_GOPS = {"vgg16": 307.8, "resnet34": 281.8, "mobilenet_v1": 268.92}


def run() -> dict:
    rows = []
    for net, (vwa_util, vwa_gops) in VWA.items():
        perf = run_network(net)
        ours = perf.throughput_gops_paper
        rows.append({
            "network": net,
            "ours_util_%": round(perf.mean_layer_utilization * 100, 1),
            "ours_GOPS": round(ours, 1),
            "paper_GOPS": PAPER_GOPS[net],
            "vwa[15]_GOPS": vwa_gops,
            "gain_vs_vwa_%": round((ours / vwa_gops - 1) * 100, 1),
        })
    print(fmt_table(rows, list(rows[0])))
    pes = cost_adjusted_pe_count()
    print(f"PE count: {pes} cost-adjusted vs 168 in [15] "
          f"({(1 - pes/168)*100:.0f}% fewer)")
    ok = all(abs(r["ours_GOPS"] - r["paper_GOPS"]) / r["paper_GOPS"] < 0.04
             for r in rows) and all(r["gain_vs_vwa_%"] > 70 for r in rows)
    print("paper claims (GOPS ±4%, ≥77% gain over [15]):",
          "REPRODUCED" if ok else "FAIL")
    return {"rows": rows, "adjusted_pes": pes, "ok": ok}
