"""Run every paper table/figure benchmark.  One module per artifact.

    PYTHONPATH=src python -m benchmarks.run [--only fig19_utilization ...]

Prints each benchmark's table, then a ``name,us_per_call,derived`` CSV
summary (derived = the headline number + REPRODUCED/FAIL verdict).
"""

from __future__ import annotations

import argparse
import sys

from . import (ablation_grad_compress, attention_kernels, conv_kernels,
               fig1_quant, fig17_pe_cost, fig19_utilization, fig20_throughput,
               table2_comparison, table3_latency, telemetry_overhead)
from .common import timed

BENCHES = {
    "fig1_quant": (fig1_quant, "snr_gain_db"),
    "conv_kernels": (conv_kernels, "mean_blockwise_overhead_x"),
    "attention_kernels": (attention_kernels, "min_gqa4_traffic_win_x"),
    "fig17_pe_cost": (fig17_pe_cost, "tput_per_pe"),
    "fig19_utilization": (fig19_utilization, None),
    "fig20_throughput": (fig20_throughput, "adjusted_pes"),
    "table2_comparison": (table2_comparison, "peak_gops"),
    "table3_latency": (table3_latency, "total_ms"),
    "ablation_grad_compress": (ablation_grad_compress, "ef_gap"),
    "telemetry_overhead": (telemetry_overhead, "overhead_pct"),
}


ALIASES = {"conv": "conv_kernels",  # short names accepted by --only
           "attention": "attention_kernels",
           "telemetry": "telemetry_overhead"}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*",
                    choices=list(BENCHES) + list(ALIASES))
    args = ap.parse_args(argv)
    names = [ALIASES.get(n, n) for n in (args.only or list(BENCHES))]

    summary = []
    ok_all = True
    for name in names:
        mod, key = BENCHES[name]
        print(f"\n=== {name} " + "=" * max(0, 60 - len(name)))
        out, us = timed(mod.run)
        derived = f"{out.get(key):.4g}" if key and out.get(key) is not None \
            else ("ok" if out.get("ok") else "fail")
        verdict = "REPRODUCED" if out.get("ok") else "FAIL"
        ok_all &= bool(out.get("ok"))
        summary.append(f"{name},{us:.0f},{derived} [{verdict}]")

    print("\nname,us_per_call,derived")
    for line in summary:
        print(line)
    print(f"\noverall: "
          f"{'ALL PAPER CLAIMS REPRODUCED' if ok_all else 'SOME FAILED'}")
    return 0 if ok_all else 1


if __name__ == "__main__":
    sys.exit(main())
