"""Table 2 — comparison with previous designs.

The numbers for prior accelerators are the paper's own reported values
(they are literature constants, not things we can re-measure); the
NeuroMAX column is *computed* from our models: peak throughput from the
grid geometry, PE count from the cost model, utilization-scaled GOPS from
the dataflow simulator."""

from __future__ import annotations

from repro.core.cost_model import (N_PES, N_THREADS, TOTAL_ACCEL_LUTS,
                                   cost_adjusted_pe_count,
                                   peak_throughput_per_pe)
from repro.core.dataflow import (CLOCK_HZ, PEAK_GOPS_PAPER,
                                 PEAK_OPS_PER_CYCLE)

from .common import fmt_table

PRIOR = [
    {"design": "[7] Eyeriss", "PEs": 168, "peak_GOPS": 84.0,
     "tput/PE": 0.5},
    {"design": "[8] Zynq-7100", "PEs": 1926, "peak_GOPS": 17.11,
     "tput/PE": 0.008},
    {"design": "[9] Arria-10", "PEs": 1278, "peak_GOPS": 170.6,
     "tput/PE": 0.13},
    {"design": "[10] Eyeriss v2", "PEs": 192, "peak_GOPS": 153.6,
     "tput/PE": 0.8},
    {"design": "[15] VWA", "PEs": 168, "peak_GOPS": 168.0, "tput/PE": 1.0},
]


def run() -> dict:
    # Table 2 uses the paper's own accounting (Fig-20/Table-2 rows are
    # exactly util × 324 GOPS): 324 thread-MACs/cycle ≡ "324 GOPS".  The
    # plain-physics number (324 × 200 MHz = 64.8 GMAC/s) is reported by
    # NetworkPerf.gmacs_per_s; comparisons here stay in paper units.
    peak = PEAK_GOPS_PAPER
    pes = cost_adjusted_pe_count()
    tput_pe = peak_throughput_per_pe()
    ours = {"design": "NeuroMAX (ours)", "PEs": pes,
            "peak_GOPS": round(peak, 1), "tput/PE": round(tput_pe, 2)}
    rows = [ours] + PRIOR
    print(fmt_table(rows, ["design", "PEs", "peak_GOPS", "tput/PE"]))
    best_prior = max(p["tput/PE"] for p in PRIOR)
    print(f"peak {peak:.0f} GOPS (paper accounting) from {N_PES} PEs × "
          f"{N_THREADS} threads = {PEAK_OPS_PER_CYCLE} threads @ "
          f"{CLOCK_HZ/1e6:.0f} MHz; LUTs {TOTAL_ACCEL_LUTS/1e3:.1f}k")
    ok = abs(peak - 324.0) < 1e-6 and pes == 122 and \
        tput_pe > 2.5 * best_prior
    print("paper claims (324 GOPS, 122 PEs, ≥2.5× best prior tput/PE):",
          "REPRODUCED" if ok else "FAIL")
    return {"rows": rows, "peak_gops": peak, "ok": ok}
