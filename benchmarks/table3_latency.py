"""Table 3 — VGG16 layer-by-layer latency.

Paper totals: NeuroMAX 240.23 ms, [7] 3755.3 ms, [15] 457.5 ms (after the
paper's 200 MHz normalisation of [15]).  Our dataflow simulator reproduces
the per-layer NeuroMAX column; the conv1_1 anomaly (paper reports 1.35 ms,
which implies 2× the per-thread rate of every other layer) is flagged
rather than overfit — see EXPERIMENTS.md."""

from __future__ import annotations

from repro.core.accelerator import run_network

from .common import fmt_table

PAPER = {  # ms
    "CONV1_1": 1.35, "CONV1_2": 28.9, "CONV2_1": 14.4, "CONV2_2": 29.26,
    "CONV3_1": 14.54, "CONV3_2": 28.6, "CONV3_3": 28.7, "CONV4_1": 14.4,
    "CONV4_2": 29.0, "CONV4_3": 29.5, "CONV5_1": 7.24, "CONV5_2": 7.23,
    "CONV5_3": 7.11,
}
PAPER_TOTAL = 240.23
PRIOR_TOTALS = {"[7]": 3755.3, "[15]": 457.5}


def run() -> dict:
    perf = run_network("vgg16")
    rows = []
    total = 0.0
    for lp in perf.layers:
        ours = lp.latency_ms
        total += ours
        paper = PAPER.get(lp.spec.name)
        rows.append({"layer": lp.spec.name, "ours_ms": round(ours, 2),
                     "paper_ms": paper,
                     "delta_%": round((ours / paper - 1) * 100, 1)
                     if paper else None})
    rows.append({"layer": "TOTAL", "ours_ms": round(total, 2),
                 "paper_ms": PAPER_TOTAL,
                 "delta_%": round((total / PAPER_TOTAL - 1) * 100, 1)})
    print(fmt_table(rows, ["layer", "ours_ms", "paper_ms", "delta_%"]))
    for ref, t in PRIOR_TOTALS.items():
        print(f"vs {ref}: {(1 - total / t) * 100:.0f}% lower latency "
              f"(paper: {(1 - PAPER_TOTAL / t) * 100:.0f}%)")
    # aggregate within ±4 %; non-anomalous layers within ±3 %
    layer_ok = all(abs(r["delta_%"]) <= 3.0 for r in rows[1:-1]
                   if r["paper_ms"])
    ok = abs(total / PAPER_TOTAL - 1) < 0.04 and layer_ok
    print("paper claims (total ±4%, layers ±3% except conv1_1):",
          "REPRODUCED" if ok else "FAIL")
    return {"rows": rows, "total_ms": total, "ok": ok}
