"""Telemetry overhead probe: disabled-path cost must stay in the noise.

The obs layer's contract is "near-zero cost when disabled" — this probe
measures it instead of trusting it:

  1. engine A/B: per-decode-step wall time of a `ServeEngine` with
     ``telemetry="off"`` (hard-bypassed hooks, the no-telemetry control)
     vs ``telemetry="auto"`` with every obs gate forced off (the shipping
     default).  The "auto" path pays only the gate checks; acceptance is
     **< 3 % overhead** (min-of-trials, alternating, so machine noise
     cancels).
  2. primitive micro-costs: ns per disabled `span()` / `instant()` /
     gate check, for the README numbers.
  3. an **enabled** run (informational, not gated) that also exports the
     CI artifacts: ``results/telemetry/trace.json`` (Chrome trace) and
     ``results/telemetry/metrics_snapshot.json``.
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.models import transformer
from repro.obs import kernel_profile as kprof
from repro.obs import trace as obs_trace
from repro.serving.engine import EngineConfig, Request, ServeEngine

from .common import fmt_table, write_json

OVERHEAD_THRESHOLD_PCT = 3.0
ARTIFACT_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "results", "telemetry")


def _small_model():
    cfg = get_config("gemma-2b").reduced(n_layers=2, vocab=64, d_model=16,
                                         d_ff=32, head_dim=8, n_heads=2)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _make_engine(cfg, params, mode):
    return ServeEngine(cfg, params, EngineConfig(
        max_batch=4, max_prompt=16, max_len=4096, telemetry=mode))


def _feed(eng, cfg, n=4, max_new=10**6, seed=0):
    rng = np.random.default_rng(seed)
    for uid in range(n):
        T = int(rng.integers(2, 6))
        eng.submit(Request(
            uid=uid, prompt=rng.integers(1, cfg.vocab, size=T)
            .astype(np.int32), max_new_tokens=max_new))


def _time_steps(eng, steps: int) -> float:
    t0 = time.perf_counter()
    for _ in range(steps):
        eng.step()
    return (time.perf_counter() - t0) / steps * 1e6  # µs/step


def _disabled_ns(fn, n=50_000) -> float:
    t0 = time.perf_counter_ns()
    for _ in range(n):
        fn()
    return (time.perf_counter_ns() - t0) / n


def run() -> dict:
    cfg, params = _small_model()

    # ------------------------------------------------- A/B: off vs auto-off
    # force every obs gate off so "auto" measures the shipping default
    # even if the environment carries REPRO_TRACE
    obs_trace.set_enabled(False)
    kprof.set_enabled(False)
    engines = {}
    for mode in ("off", "auto"):
        eng = _make_engine(cfg, params, mode)
        _feed(eng, cfg)
        _time_steps(eng, 10)                       # compile + warm
        engines[mode] = eng

    trials = {m: [] for m in engines}
    for _ in range(5):
        for mode, eng in engines.items():          # alternate modes
            trials[mode].append(_time_steps(eng, 20))
    best = {m: min(v) for m, v in trials.items()}
    overhead_pct = (best["auto"] / best["off"] - 1.0) * 100.0

    # ----------------------------------------- disabled primitive costs
    span_ns = _disabled_ns(lambda: obs_trace.span("x"))
    instant_ns = _disabled_ns(lambda: obs_trace.instant("x"))
    gate_ns = _disabled_ns(kprof.enabled)

    # -------------------------- enabled run (informational) + artifacts
    obs_trace.set_enabled(True)
    kprof.set_enabled(True)
    obs_trace.clear()
    kprof.clear()
    eng_on = _make_engine(cfg, params, "auto")
    _feed(eng_on, cfg, max_new=100, seed=1)        # outlasts the timed steps
    _time_steps(eng_on, 10)
    on_us = min(_time_steps(eng_on, 20) for _ in range(3))
    eng_on.run(max_iters=200)                      # retire → tokens/s rows
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    obs_trace.export_chrome_trace(os.path.join(ARTIFACT_DIR, "trace.json"))
    with open(os.path.join(ARTIFACT_DIR, "metrics_snapshot.json"),
              "w") as f:
        json.dump(eng_on.metrics_snapshot(), f, indent=1, sort_keys=True,
                  default=str)
        f.write("\n")
    obs_trace.set_enabled(None)
    kprof.set_enabled(None)

    ok = overhead_pct < OVERHEAD_THRESHOLD_PCT
    rows = [
        {"case": "engine_off", "steady_us": round(best["off"], 1),
         "note": "no-telemetry control"},
        {"case": "engine_auto_disabled", "steady_us": round(best["auto"], 1),
         "note": f"overhead {overhead_pct:+.2f}% (limit "
                 f"{OVERHEAD_THRESHOLD_PCT}%)"},
        {"case": "engine_traced", "steady_us": round(on_us, 1),
         "note": "REPRO_TRACE=1 path, informational"},
        {"case": "span_disabled", "steady_us": round(span_ns / 1e3, 4),
         "note": f"{span_ns:.0f} ns/call"},
        {"case": "instant_disabled", "steady_us": round(instant_ns / 1e3, 4),
         "note": f"{instant_ns:.0f} ns/call"},
        {"case": "profiler_gate", "steady_us": round(gate_ns / 1e3, 4),
         "note": f"{gate_ns:.0f} ns/check"},
    ]
    print(fmt_table(rows, ["case", "steady_us", "note"]))
    print(f"telemetry-disabled overhead: {overhead_pct:+.2f}% "
          f"({'OK' if ok else 'FAIL'}, limit {OVERHEAD_THRESHOLD_PCT}%)")
    payload = {"rows": rows, "overhead_pct": round(overhead_pct, 3),
               "threshold_pct": OVERHEAD_THRESHOLD_PCT,
               "span_disabled_ns": round(span_ns, 1),
               "instant_disabled_ns": round(instant_ns, 1),
               "profiler_gate_ns": round(gate_ns, 1),
               "artifacts": [os.path.join("results", "telemetry", n)
                             for n in ("trace.json",
                                       "metrics_snapshot.json")],
               "ok": ok}
    write_json("BENCH_telemetry.json", payload)
    return payload


if __name__ == "__main__":
    run()
