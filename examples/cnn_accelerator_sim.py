"""The paper's own workload end to end: a log-quantized CNN trained in JAX
with the accelerator's numerics, then 'deployed' onto the NeuroMAX
dataflow model for per-layer utilization/latency — i.e. software-hardware
co-design in one script.

    PYTHONPATH=src python examples/cnn_accelerator_sim.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accelerator import NETWORKS, run_network
from repro.models.cnn import cnn_loss, make_cnn


def train_quantized_cnn(steps=250):
    """Tiny SqueezeNet with logq6 fake-quant (the accelerator's numerics),
    fit on a fixed synthetic 8-class set (SGD + momentum)."""
    key = jax.random.PRNGKey(0)
    params, apply_fn = make_cnn("squeezenet", key, n_classes=8,
                                width_mult=0.25, quant="logq6")
    r = np.random.default_rng(0)
    y = np.tile(np.arange(8), 4).astype(np.int32)          # 32 samples
    x = r.normal(size=(32, 32, 32, 3)).astype(np.float32)
    # class-dependent frequency pattern (needs actual features, not bias)
    grid = np.linspace(0, 2 * np.pi, 32)
    for i, yy in enumerate(y):
        x[i, :, :, 0] += 2.0 * np.sin((yy + 1) * grid)[None, :]
    batch = {"images": jnp.asarray(x), "labels": jnp.asarray(y)}

    mom = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step_fn(p, m, b, lr):
        (loss, aux), g = jax.value_and_grad(
            lambda pp: cnn_loss(apply_fn, pp, b), has_aux=True)(p)
        gn = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(g)))
        g = jax.tree.map(lambda x: x * jnp.minimum(1.0, 1.0 / gn), g)
        m = jax.tree.map(lambda mm, gg: 0.9 * mm + gg, m, g)
        p = jax.tree.map(lambda pp, mm: pp - lr * mm, p, m)
        return loss, aux["acc"], p, m

    for s in range(steps):
        lr = 0.02 * (1.0 - 0.8 * s / steps)          # linear decay
        loss, acc, params, mom = step_fn(params, mom, batch, lr)
        if s % 30 == 0 or s == steps - 1:
            print(f"  step {s:3d}  loss {float(loss):.3f} "
                  f"acc {float(acc)*100:.0f}%")
    return float(loss), float(acc), params, apply_fn, batch


def serve_packed(params, apply_fn, batch):
    """Deployed numerics: pack conv weights to int8 log codes once, route
    every conv through kernels/ops.conv2d (the three-tier dispatch layer)."""
    import functools
    from repro.serving.quantize import quantize_cnn_params, quantized_fraction

    qparams = quantize_cnn_params(params)
    apply_q = functools.partial(apply_fn, conv_impl="blockwise")
    logits_fq = apply_fn(params, batch["images"])
    logits_q = apply_q(qparams, batch["images"])
    acc = float(jnp.mean(jnp.argmax(logits_q, -1) == batch["labels"]))
    drift = float(jnp.max(jnp.abs(logits_q - logits_fq)))
    print(f"  packed {quantized_fraction(qparams)*100:.0f}% of param bytes "
          f"to int8 codes; serving acc {acc*100:.0f}%, "
          f"max logit drift vs fake-quant {drift:.2e}")
    # the demo's claim: deployed packed-code numerics == QAT numerics
    assert drift < 1e-3 * float(jnp.max(jnp.abs(logits_fq)) + 1), drift
    return acc


def main():
    print("1. training SqueezeNet (logq6 fake-quant = accelerator "
          "numerics):")
    loss, acc, params, apply_fn, batch = train_quantized_cnn()
    if acc <= 0.5:  # QAT-from-scratch on 32 samples is seed-sensitive
        print(f"  (warning: train acc only {acc*100:.0f}% this run)")

    print("\n2. serving with packed int8 log codes (kernels/ops.conv2d "
          "dispatch):")
    acc_q = serve_packed(params, apply_fn, batch)
    assert abs(acc_q - acc) < 0.2, "packed-weight serving lost the model"

    print("\n3. deploying onto the NeuroMAX grid (dataflow model):")
    for net in NETWORKS:
        perf = run_network(net)
        print(f"  {net:13s} util {perf.mean_layer_utilization*100:5.1f}%  "
              f"{perf.throughput_gops_paper:6.1f} GOPS  "
              f"latency {perf.latency_ms:7.2f} ms  "
              f"DDR {perf.ddr_bytes_log/2**20:6.1f} MiB/inference "
              f"(vs {perf.ddr_bytes_fp16/2**20:.1f} MiB fp16 — "
              f"{perf.ddr_bytes_fp16/perf.ddr_bytes_log:.2f}× saved)")
    print("\nThe log codes cut DDR traffic ≈2.3× — on TPU the same codes cut "
          "HBM weight traffic 2.67× vs bf16 (see EXPERIMENTS.md §Perf).")


if __name__ == "__main__":
    main()
