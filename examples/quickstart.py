"""Quickstart: the paper's technique end to end in five minutes.

1. log-quantize a weight matrix to 6-bit base-√2 codes (paper §3);
2. multiply with the log-domain shift+LUT semantics (paper §4, eq. 8) and
   check it against the float product;
3. run a 3×3 convolution through the functional NeuroMAX 6×3×6 PE-grid
   model (paper §5) and check it against lax.conv;
4. analyze VGG16 on the accelerator dataflow model (paper §6);
5. call the framework's log_matmul op (the TPU-native form of the same
   idea: codes decoded in VMEM next to the MXU).

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accelerator import run_network
from repro.core.logmath import LogPEThread
from repro.core.logquant import DEFAULT, log_dequantize, log_quantize, \
    quantize_tensor
from repro.core.pe_grid import PEGrid
from repro.kernels import ops

# 1 — quantize ---------------------------------------------------------------
rng = np.random.default_rng(0)
w = rng.normal(size=(8, 8)).astype(np.float32) * 0.1
packed, scale = log_quantize(jnp.asarray(w), DEFAULT)
deq = np.asarray(log_dequantize(packed, scale, DEFAULT))
rel = np.abs(deq - w) / np.abs(w)
print(f"1. 6-bit base-√2 codes: median |rel err| = {np.median(rel)*100:.1f}% "
      f"(bound 2^(1/4)-1 = 18.9%)")

# 2 — shift+LUT product (eq. 8) ----------------------------------------------
thread = LogPEThread()
wq, aq = -3, -5                      # codes: w = 2^(-1.5), a = 2^(-2.5)
got = thread.to_float(thread(wq, aq))
want = 2.0 ** (wq / 2) * 2.0 ** (aq / 2)
print(f"2. log-PE thread: LUT(frac)>>~int = {got:.6f}, closed form "
      f"{want:.6f}  (Δ={abs(got-want):.2e})")

# 3 — PE grid conv (§5.1) -----------------------------------------------------
x = rng.normal(size=(12, 6, 1)).astype(np.float32)
k = rng.normal(size=(3, 3, 1, 1)).astype(np.float32)
grid = PEGrid(mode="float")
out, stats = grid.conv2d(x, k, stride=1)
out = out[:, :, 0]
ref = jax.lax.conv_general_dilated(
    jnp.asarray(x)[None], jnp.asarray(k),
    (1, 1), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC"))[0, :, :, 0]
# C=1 occupies 1 of 6 PE matrices; §5.1's 83.3% counts the active matrix
print(f"3. PE-grid 3×3 conv matches lax.conv: "
      f"{np.allclose(out, np.asarray(ref), atol=1e-4)}; "
      f"active-matrix utilization {stats.active_utilization*100:.1f}% "
      f"(paper §5.1: 83.3%), "
      f"stored psums {stats.psum_storage_fraction*100:.0f}% (paper: 11%)")

# 4 — whole-CNN analysis (§6) -------------------------------------------------
perf = run_network("vgg16")
print(f"4. VGG16 on NeuroMAX: util {perf.mean_layer_utilization*100:.1f}% "
      f"(paper 95%), {perf.throughput_gops_paper:.1f} GOPS (paper 307.8), "
      f"latency {perf.latency_ms:.1f} ms (paper 240.23)")

# 5 — the TPU-native op -------------------------------------------------------
x = jnp.asarray(rng.normal(size=(4, 256)).astype(np.float32))
qt = quantize_tensor(jnp.asarray(rng.normal(size=(256, 128)) * 0.05,
                                 jnp.float32))
y = ops.log_matmul(x, qt)
y_ref = x @ qt.dequantize(jnp.float32)
err = float(jnp.max(jnp.abs(y - y_ref)))
print(f"5. kernels.ops.log_matmul (decode-in-VMEM): max|Δ| vs dequant "
      f"matmul = {err:.2e}")
print("done.")
