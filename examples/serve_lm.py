"""Serve a small model with batched requests through the continuous-
batching engine — mixed prompt lengths, temperature/greedy mix, slot
refill, plus a correctness spot-check against naive decode.

    PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.models import transformer
from repro.serving.engine import EngineConfig, Request, ServeEngine


def main():
    cfg = get_config("gemma3-1b").reduced(n_layers=4)  # local+global mix
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, EngineConfig(
        max_batch=4, max_prompt=32, max_len=64))

    rng = np.random.default_rng(0)
    n_req = 10
    for uid in range(n_req):
        T = int(rng.integers(3, 16))
        prompt = rng.integers(1, cfg.vocab, size=T).astype(np.int32)
        engine.submit(Request(uid=uid, prompt=prompt, max_new_tokens=12,
                              temperature=0.0 if uid % 2 else 0.8,
                              seed=uid))

    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    print(f"served {len(done)}/{n_req} requests, {toks} tokens in {dt:.1f}s"
          f" ({toks/dt:.1f} tok/s incl. compile)  stats={engine.stats}")

    # spot-check one greedy request against naive full-forward decode
    req = next(r for r in done if r.temperature == 0.0)
    toks_ref = list(req.prompt)
    for _ in range(len(req.output)):
        h, _, _ = transformer.forward(
            params, jnp.asarray([toks_ref], jnp.int32), cfg)
        logits = transformer.logits_fn(params, h[:, -1:], cfg)
        toks_ref.append(int(jnp.argmax(logits[0, 0])))
    ok = toks_ref[len(req.prompt):] == req.output
    print(f"greedy request {req.uid} matches naive decode: {ok}")
    assert ok
    return done


if __name__ == "__main__":
    main()
