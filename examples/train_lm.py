"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the paper's technique on (logq6 fake-quant weights) and log-compressed
gradients, checkpointing and resuming along the way.

    PYTHONPATH=src python examples/train_lm.py [--steps 300] [--params-m 100]

Uses a gemma-family config scaled to ~--params-m million parameters — the
same model/trainer/checkpoint stack the production launcher uses, on the
host mesh.  Expect a clear loss drop (≈10.4 = ln V → ≈3 on the synthetic
zipf stream).
"""

import argparse
import dataclasses
import tempfile

import jax

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, ShardedLoader
from repro.models import transformer
from repro.runtime.checkpoint import CheckpointManager
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import TrainConfig, train


def scaled_config(params_m: float):
    """gemma-family config with ≈params_m million parameters."""
    base = get_config("gemma-2b")
    d = 512
    cfg = dataclasses.replace(
        base, n_layers=8, d_model=d, n_heads=8, n_kv_heads=1, head_dim=64,
        d_ff=4 * d, vocab=32_768, quant="logq6", remat=False,
        attn_block_k=256)
    # grow width until the analytic count reaches the target
    while cfg.param_count() < params_m * 1e6:
        d += 128
        cfg = dataclasses.replace(cfg, d_model=d, d_ff=4 * d)
    return cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--params-m", type=float, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--grad-compress", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args(argv)

    cfg = scaled_config(args.params_m)
    print(f"model: {cfg.param_count()/1e6:.0f}M params, d={cfg.d_model}, "
          f"{cfg.n_layers}L, quant={cfg.quant}")

    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    loader = ShardedLoader(DataConfig(seq_len=args.seq,
                                      global_batch=args.batch,
                                      vocab=cfg.vocab, seed=0))
    tcfg = TrainConfig(
        opt=OptimizerConfig(lr=3e-3, warmup_steps=30,
                            total_steps=args.steps),
        grad_compress=args.grad_compress, log_every=20,
        xent_chunk=min(256, args.seq))
    loss_fn = lambda p, b: transformer.lm_loss(p, b, cfg,
                                               xent_chunk=tcfg.xent_chunk)

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="train_lm_ckpt_")
    mgr = CheckpointManager(ckpt_dir, keep=2)
    hooks = [mgr.hook(every=100),
             lambda s, st, m: print(f"  step {s:4d} loss {m['loss']:.4f} "
                                    f"gnorm {m['grad_norm']:.2f}")]

    state, hist = train(loss_fn, params, loader, tcfg,
                        num_steps=args.steps, hooks=hooks)
    mgr.save(int(state["step"]), state, sync=True)
    print(f"first loss {hist[0]['loss']:.4f} → final {hist[-1]['loss']:.4f}"
          f"  (ckpts in {ckpt_dir})")
    assert hist[-1]["loss"] < hist[0]["loss"] - 1.0, "training failed"
    return hist


if __name__ == "__main__":
    main()
