"""repro: NeuroMAX (log-quantized, multi-threaded dataflow) in JAX/Pallas.

Subpackages:
  core      paper's contribution: log quantization, log-PE math, PE grid +
            2D weight-broadcast dataflow models
  kernels   Pallas TPU kernels (log_matmul, flash_attention, wkv6) + oracles
  models    transformer zoo (dense/GQA/MoE/RWKV6/RG-LRU) + CNN substrate
  configs   assigned architectures
  data      input pipeline
  training  optimizers, grad compression, train loop
  serving   KV-cache engine
  runtime   checkpoint/restore, elastic resharding, monitoring
  launch    mesh, dry-run, train/serve drivers
  analysis  roofline
"""
__version__ = "1.0.0"
