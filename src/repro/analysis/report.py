"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
records, and render live-telemetry snapshots.

    PYTHONPATH=src python -m repro.analysis.report --dir results/dryrun
    PYTHONPATH=src python -m repro.analysis.report --metrics snapshot.json

``--metrics`` takes a JSON snapshot (`ServeEngine.metrics_snapshot()` or
the kernel profiler's `snapshot()`) and prints the per-op utilization
table — analytic bytes moved vs achieved bandwidth against the HBM
roofline, echoing the paper's per-layer utilization analysis (§V) from
*measured* dispatches instead of offline benchmarks.
"""

from __future__ import annotations

import argparse
import json
import os
from collections import defaultdict

from ..configs.base import SHAPES
from ..configs.registry import ARCH_NAMES
from .roofline import HBM_BW, from_record, load_records


def dryrun_table(recs: list[dict]) -> str:
    """One row per (arch, shape): single- and multi-pod status + memory."""
    by_key = {(r["arch"], r["shape"], r["mesh"]): r for r in recs}
    lines = [
        "| arch | shape | step | 1-pod (256c) | GiB/dev | 2-pod (512c) | "
        "GiB/dev | collectives (1-pod, per unit-iter) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            s = by_key.get((arch, shape, "single"))
            m = by_key.get((arch, shape, "multi"))
            if s is None:
                continue
            if s.get("skipped"):
                lines.append(f"| {arch} | {shape} | — | skipped "
                             f"(full-attention @500k) | | skipped | | |")
                continue

            def fmt(r):
                if r is None:
                    return "—", ""
                if "error" in r:
                    return "FAIL", ""
                mem = (r["memory"]["temp_bytes"]
                       + r["memory"]["argument_bytes"]) / 2**30
                return "ok", f"{mem:.1f}"

            s_st, s_mem = fmt(s)
            m_st, m_mem = fmt(m)
            cc = s.get("collectives_prod_once", {}).get("counts", {})
            cstr = " ".join(f"{k.split('-')[-1][:4]}:{v}"
                            for k, v in sorted(cc.items()))
            lines.append(f"| {arch} | {shape} | {s.get('step_kind','')} "
                         f"| {s_st} | {s_mem} | {m_st} | {m_mem} | {cstr} |")
    return "\n".join(lines)


def roofline_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | t_comp ms | t_mem ms | t_coll ms | bound | "
        "useful | roofline-MFU | GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in recs:
        if rec.get("mesh") != "single" or rec.get("skipped") \
                or "cost_true" not in rec:
            continue
        r = from_record(rec)
        lines.append(
            f"| {r.arch} | {r.shape} | {r.t_compute*1e3:.2f} "
            f"| {r.t_memory*1e3:.2f} | {r.t_collective*1e3:.2f} "
            f"| {r.bottleneck[:4]} | {r.useful_flops_ratio:.2f} "
            f"| {r.mfu*100:.1f}% | {r.memory_per_dev/2**30:.1f} |")
    return "\n".join(lines)


def summary(recs: list[dict]) -> str:
    n_ok = sum(1 for r in recs if "error" not in r and not r.get("skipped"))
    n_skip = sum(1 for r in recs if r.get("skipped"))
    n_fail = sum(1 for r in recs if "error" in r)
    bounds = defaultdict(int)
    worst = []
    for rec in recs:
        if rec.get("mesh") != "single" or rec.get("skipped") \
                or "cost_true" not in rec:
            continue
        r = from_record(rec)
        bounds[r.bottleneck] += 1
        worst.append((r.mfu, f"{r.arch}/{r.shape}"))
    worst.sort()
    out = [f"- {n_ok} compiled ok, {n_skip} skipped (per assignment), "
           f"{n_fail} failed",
           f"- bottleneck split: {dict(bounds)}",
           f"- lowest roofline-MFU cells: "
           + ", ".join(f"{n} ({m*100:.1f}%)" for m, n in worst[:3])]
    return "\n".join(out)


def _fmt_bytes(n) -> str:
    if n >= 2**20:
        return f"{n/2**20:.2f} MiB"
    return f"{n/2**10:.1f} KiB"


def per_op_utilization_table(snap: dict) -> str:
    """Per-dispatch utilization rows from a telemetry snapshot: analytic
    bytes moved (the paper's traffic accounting) over measured steady time
    → achieved GB/s, as a fraction of the HBM roofline."""
    recs = snap.get("kernels", snap).get("records", [])
    lines = ["| op | impl | shape key | calls | bytes moved | steady µs | "
             "GB/s | %HBM roofline | timing |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["op"], r["impl"], r["key"])):
        total = (r.get("bytes") or {}).get("total", 0)
        calls = r.get("calls", 0) + r.get("traced_calls", 0)
        us = r.get("steady_us")
        if us:
            gbps = total / (us * 1e-6) / 1e9
            util = f"{100 * gbps * 1e9 / HBM_BW:.2f}%"
            us_s, gb_s = f"{us:.1f}", f"{gbps:.3f}"
        else:
            us_s, gb_s, util = "—", "—", "—"
        lines.append(f"| {r['op']} | {r['impl']} | `{r['key']}` | {calls} "
                     f"| {_fmt_bytes(total)} | {us_s} | {gb_s} | {util} "
                     f"| {r.get('steady_source') or '—'} |")
    return "\n".join(lines)


def _histogram_rows(hists: dict) -> str:
    lines = ["| metric | count | mean | p50 | p90 | p99 |",
             "|---|---|---|---|---|---|"]
    for name, h in sorted(hists.items()):
        lines.append(f"| {name} | {h['count']} | {h['mean']:.4g} "
                     f"| {h['p50']:.4g} | {h['p90']:.4g} | {h['p99']:.4g} |")
    return "\n".join(lines)


def metrics_report(snap: dict) -> str:
    """Full rendering of a telemetry snapshot: engine latency histograms,
    per-op utilization, program timings, autotune hit/miss."""
    out = ["## §Telemetry — per-op utilization (measured dispatches)", "",
           per_op_utilization_table(snap)]
    progs = snap.get("kernels", snap).get("programs", {})
    if progs:
        out += ["", "### Programs (jitted engine calls)", "",
                "| program | calls | first (compile) µs | steady µs |",
                "|---|---|---|---|"]
        for name, p in sorted(progs.items()):
            steady = f"{p['steady_us']:.1f}" if p.get("steady_us") else "—"
            out.append(f"| {name} | {p['calls']} | {p['first_us']:.1f} "
                       f"| {steady} |")
    hists = snap.get("engine", {}).get("histograms", {})
    if hists:
        out += ["", "### Engine latency (seconds / tokens-per-s)", "",
                _histogram_rows(hists)]
    counters = snap.get("global", {}).get("counters", {})
    tuned = {k: v for k, v in counters.items() if "autotune" in k}
    if tuned:
        out += ["", "### Autotune table", ""]
        out += [f"- {k}: {v}" for k, v in sorted(tuned.items())]
    return "\n".join(out) + "\n"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--out", default="")
    ap.add_argument("--metrics", default="",
                    help="telemetry snapshot JSON (metrics_snapshot()); "
                         "prints the per-op utilization report instead of "
                         "the dry-run tables")
    args = ap.parse_args()
    if args.metrics:
        with open(args.metrics) as f:
            text = metrics_report(json.load(f))
    else:
        recs = load_records(args.dir)
        text = ("## §Dry-run\n\n" + summary(recs) + "\n\n"
                + dryrun_table(recs)
                + "\n\n## §Roofline (single-pod, 256 chips)"
                + "\n\n" + roofline_table(recs) + "\n")
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    print(text)


if __name__ == "__main__":
    main()
