"""Generate EXPERIMENTS.md §Dry-run and §Roofline tables from the dry-run
records.

    PYTHONPATH=src python -m repro.analysis.report --dir results/dryrun
"""

from __future__ import annotations

import argparse
import json
import os
from collections import defaultdict

from ..configs.base import SHAPES
from ..configs.registry import ARCH_NAMES
from .roofline import from_record, load_records


def dryrun_table(recs: list[dict]) -> str:
    """One row per (arch, shape): single- and multi-pod status + memory."""
    by_key = {(r["arch"], r["shape"], r["mesh"]): r for r in recs}
    lines = [
        "| arch | shape | step | 1-pod (256c) | GiB/dev | 2-pod (512c) | "
        "GiB/dev | collectives (1-pod, per unit-iter) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_NAMES:
        for shape in SHAPES:
            s = by_key.get((arch, shape, "single"))
            m = by_key.get((arch, shape, "multi"))
            if s is None:
                continue
            if s.get("skipped"):
                lines.append(f"| {arch} | {shape} | — | skipped "
                             f"(full-attention @500k) | | skipped | | |")
                continue

            def fmt(r):
                if r is None:
                    return "—", ""
                if "error" in r:
                    return "FAIL", ""
                mem = (r["memory"]["temp_bytes"]
                       + r["memory"]["argument_bytes"]) / 2**30
                return "ok", f"{mem:.1f}"

            s_st, s_mem = fmt(s)
            m_st, m_mem = fmt(m)
            cc = s.get("collectives_prod_once", {}).get("counts", {})
            cstr = " ".join(f"{k.split('-')[-1][:4]}:{v}"
                            for k, v in sorted(cc.items()))
            lines.append(f"| {arch} | {shape} | {s.get('step_kind','')} "
                         f"| {s_st} | {s_mem} | {m_st} | {m_mem} | {cstr} |")
    return "\n".join(lines)


def roofline_table(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | t_comp ms | t_mem ms | t_coll ms | bound | "
        "useful | roofline-MFU | GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in recs:
        if rec.get("mesh") != "single" or rec.get("skipped") \
                or "cost_true" not in rec:
            continue
        r = from_record(rec)
        lines.append(
            f"| {r.arch} | {r.shape} | {r.t_compute*1e3:.2f} "
            f"| {r.t_memory*1e3:.2f} | {r.t_collective*1e3:.2f} "
            f"| {r.bottleneck[:4]} | {r.useful_flops_ratio:.2f} "
            f"| {r.mfu*100:.1f}% | {r.memory_per_dev/2**30:.1f} |")
    return "\n".join(lines)


def summary(recs: list[dict]) -> str:
    n_ok = sum(1 for r in recs if "error" not in r and not r.get("skipped"))
    n_skip = sum(1 for r in recs if r.get("skipped"))
    n_fail = sum(1 for r in recs if "error" in r)
    bounds = defaultdict(int)
    worst = []
    for rec in recs:
        if rec.get("mesh") != "single" or rec.get("skipped") \
                or "cost_true" not in rec:
            continue
        r = from_record(rec)
        bounds[r.bottleneck] += 1
        worst.append((r.mfu, f"{r.arch}/{r.shape}"))
    worst.sort()
    out = [f"- {n_ok} compiled ok, {n_skip} skipped (per assignment), "
           f"{n_fail} failed",
           f"- bottleneck split: {dict(bounds)}",
           f"- lowest roofline-MFU cells: "
           + ", ".join(f"{n} ({m*100:.1f}%)" for m, n in worst[:3])]
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    recs = load_records(args.dir)
    text = ("## §Dry-run\n\n" + summary(recs) + "\n\n"
            + dryrun_table(recs) + "\n\n## §Roofline (single-pod, 256 chips)"
            + "\n\n" + roofline_table(recs) + "\n")
    if args.out:
        with open(args.out, "w") as f:
            f.write(text)
    print(text)


if __name__ == "__main__":
    main()
