"""Three-term roofline from compiled dry-run artifacts.

    compute    = HLO_FLOPs   / (chips × peak FLOP/s)
    memory     = HLO_bytes   / (chips × HBM bandwidth)
    collective = coll_bytes  / (chips × ICI link bandwidth)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` after the
unroll-diff correction (launch/dryrun.py): the XLA cost model counts a
while-loop body ONCE, so the dry-run lowers each program twice (layer-scan
unroll 1 and 2) and extrapolates  true = A + (trips−1)·(B−A).

collective_bytes is not in cost_analysis — ``collective_bytes()`` below
parses the post-SPMD optimized HLO (``compiled.as_text()``, where partitioner
-inserted collectives are explicit) and sums operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
Shapes in that text are already per-device.

Hardware model: TPU v5e-like — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (per assignment).
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
from collections import defaultdict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

# a shape token, e.g. ``bf16[16,4096,128]{2,1,0}`` (layout optional)
_SHAPE_RE = re.compile(r"\b([a-z]\w*?)\[([0-9,]*)\]")
# an HLO instruction line using a collective:
#   %x = RESULT_TYPE(S) all-gather(%operand, ...), replica_groups=...
# Post-optimization HLO prints operands untyped, so sizes come from the
# RESULT type(s), with per-op wire accounting below.
_COLL_RE = re.compile(
    r"=\s+(.*?)\b(" + "|".join(COLLECTIVES) + r")(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))            # [n_groups, group_size]
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return m.group(1).count(",") + 1  # explicit first group
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Per-device wire bytes of every collective op in optimized HLO.

    Accounting per op (result-shape based, since operands are untyped):
      all-gather          result bytes           (≈ (n−1)/n received)
      all-reduce          2 × result bytes       (ring: reduce-scatter +
                                                  all-gather phases)
      reduce-scatter      result bytes × group   (operand crosses the wire)
      all-to-all          Σ result tuple bytes
      collective-permute  result bytes

    Returns {"total": int, "by_type": {op: bytes}, "counts": {op: n}}."""
    by_type: dict = defaultdict(int)
    counts: dict = defaultdict(int)
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue  # async pair: the -start carries the shapes
        m = _COLL_RE.search(line)
        if not m:
            continue
        result_part, op = m.group(1), m.group(2)
        size = sum(_shape_bytes(d, dims)
                   for d, dims in _SHAPE_RE.findall(result_part))
        if op == "all-reduce":
            size *= 2
        elif op == "reduce-scatter":
            size *= _group_size(line)
        by_type[op] += size
        counts[op] += 1
    return {"total": int(sum(by_type.values())),
            "by_type": dict(by_type), "counts": dict(counts)}


# ---------------------------------------------------------------------------
# roofline terms
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_dev: float          # unroll-diff-corrected, per device
    bytes_per_dev: float
    coll_bytes_per_dev: float
    model_flops: float            # 6·N_active·D (train) or 2·N_active·D
    memory_per_dev: float         # peak (temp+args) from memory_analysis

    @property
    def t_compute(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_dev / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_dev / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Lower bound: perfectly overlapped terms → max; report max."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/redundancy waste."""
        total_hlo = self.flops_per_dev * self.chips
        return self.model_flops / total_hlo if total_hlo else float("nan")

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilisation at the roofline-bound step time."""
        t = self.step_time
        return (self.model_flops / (self.chips * PEAK_FLOPS)) / t if t else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_flops_ratio,
            "roofline_mfu": self.mfu,
            "mem_gb_per_dev": self.memory_per_dev / 2**30,
        }


def model_flops_for(cfg, shape: dict) -> float:
    """Analytic MODEL_FLOPS for the cell: 6·N_active·D (train) /
    2·N_active·D (inference), D = tokens processed in the step."""
    n = cfg.active_param_count()
    if shape["step"] == "train":
        tokens = shape["seq_len"] * shape["global_batch"]
        return 6.0 * n * tokens
    if shape["step"] == "prefill":
        tokens = shape["seq_len"] * shape["global_batch"]
        return 2.0 * n * tokens
    return 2.0 * n * shape["global_batch"]  # decode: 1 token/seq


def from_record(rec: dict) -> Roofline:
    """Build a Roofline from one dry-run JSON record."""
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        chips=rec["chips"],
        flops_per_dev=rec["cost_true"]["flops"],
        bytes_per_dev=rec["cost_true"]["bytes"],
        coll_bytes_per_dev=rec["cost_true"]["collective_bytes"],
        model_flops=rec["model_flops"],
        memory_per_dev=rec["memory"]["temp_bytes"]
        + rec["memory"]["argument_bytes"])


def load_records(directory: str) -> list[dict]:
    recs = []
    for f in sorted(os.listdir(directory)):
        if f.endswith(".json"):
            with open(os.path.join(directory, f)) as fh:
                recs.append(json.load(fh))
    return recs


def table(directory: str) -> str:
    """Markdown roofline table from a directory of dry-run records."""
    rows = []
    for rec in load_records(directory):
        if rec.get("skipped") or rec.get("mesh") != "single":
            continue
        rows.append(from_record(rec).row())
    hdr = ("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
           "bottleneck | useful | roofline-MFU | GB/dev |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.2f} "
            f"| {r['t_memory_s']*1e3:.2f} | {r['t_collective_s']*1e3:.2f} "
            f"| {r['bottleneck']} | {r['useful_ratio']:.2f} "
            f"| {r['roofline_mfu']*100:.1f}% | {r['mem_gb_per_dev']:.1f} |")
    return "\n".join(lines)


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()
    print(table(args.dir))


if __name__ == "__main__":
    main()
