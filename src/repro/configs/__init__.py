from .base import SHAPES, SUBQUADRATIC, ModelConfig, cell_is_runnable
from .registry import ARCH_NAMES, REGISTRY, get_config
