"""Model/config schema shared by every assigned architecture."""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax.numpy as jnp

# Layer kinds appearing in `layer_pattern` (the mixer of each layer):
#   attn    full causal attention
#   local   sliding-window causal attention (cfg.attn_window)
#   rec     RG-LRU recurrent block (RecurrentGemma)
#   rwkv    RWKV6 time-mix + channel-mix (replaces attn+ffn)
MIXERS = ("attn", "local", "rec", "rwkv")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    ffn: str = "swiglu"              # swiglu | geglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: tuple | None = None   # qwen2-vl M-RoPE (t, h, w) halves
    attn_window: int | None = None        # window for 'local' layers
    layer_pattern: tuple = ("attn",)      # tiled over n_layers
    # MoE (applies to the FFN of every attn/local layer when n_experts > 0)
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # RWKV
    rwkv_head_size: int = 64
    rwkv_decay_lora: int = 64
    # embeddings / head
    tie_embeddings: bool = True
    embed_inputs: bool = True        # False: frontend stub feeds embeddings
    embed_scale: bool = False        # gemma-style sqrt(d_model) scaling
    norm: str = "rmsnorm"
    # numerics
    param_dtype: Any = jnp.float32
    act_dtype: Any = jnp.bfloat16
    # paper technique: None | "logq6" (base-√2 6-bit log-quantized weights)
    quant: str | None = None
    # implementation knobs
    attn_impl: str = "blockwise"     # ref | blockwise | pallas
    attn_block_k: int = 1024
    remat: bool = True
    # layer-scan unroll (dry-run cost accounting uses 2; see launch/dryrun)
    scan_unroll: int = 1
    # --- §Perf hillclimb knobs (baseline = paper-faithful defaults) ------
    # "none": q/k/v keep the projection's column sharding (head_dim split
    #         over model → partial-sum all-reduce of score blocks).
    # "heads": explicit [batch, _, heads→model, _] constraint after the
    #         projections and before wo (Megatron-style TP attention).
    # "seq":  queries sharded over model on the sequence dim, k/v gathered
    #         (cheap for MQA/GQA) — attention math fully local per shard.
    attn_shard: str = "none"
    # "seq": residual stream sharded [batch, seq→model, _] between blocks —
    # Megatron sequence parallelism (w2/wo partial sums reduce-scatter
    # instead of all-reduce; norms run on 1/TP of the tokens).
    residual_shard: str = "none"
    # with residual_shard="seq": "fsdp" lets GSPMD choose (it gathers the
    # FFN weights — right for small d_ff), "megatron" constrains the block
    # inputs to gathered activations so weights stay TP-sharded (right when
    # weight bytes ≫ activation bytes, e.g. llama-405b d_ff=53k).
    sp_style: str = "fsdp"
    gqa_broadcast: bool = False      # einsum-broadcast GQA (no kv repeat)
    attn_acc_dtype: Any = jnp.float32  # blockwise attention math dtype
    # hybrid (griffin) recurrence width
    lru_width: int | None = None
    conv1d_width: int = 4

    # ---------------- derived ----------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def segments(self) -> tuple:
        """[(unit, n_rep), ...] — scan groups covering n_layers.

        The pattern is tiled; a remainder becomes its own single-rep unit so
        HLO size stays O(|pattern|), not O(depth)."""
        pat = tuple(self.layer_pattern)
        n_rep, rem = divmod(self.n_layers, len(pat))
        segs = []
        if n_rep:
            segs.append((pat, n_rep))
        if rem:
            segs.append((pat[:rem], 1))
        return tuple(segs)

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + layers)."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        n = V * D  # embed
        if not self.tie_embeddings:
            n += V * D
        for unit, rep in self.segments:
            for kind in unit:
                if kind == "rwkv":
                    n += rep * (5 * D * D +                  # wr,wk,wv,wg,wo
                                2 * self.rwkv_decay_lora * D +   # decay LoRA
                                2 * D * F + D * D)           # cmix ck,cv,cr
                    continue
                if kind == "rec":
                    W = self.lru_width or D
                    n += rep * (2 * D * W + W * D + 3 * W * W +
                                self.conv1d_width * W)
                else:  # attn / local
                    n += rep * (D * self.q_dim + 2 * D * self.kv_dim +
                                self.q_dim * D)
                # FFN
                fmul = 2 if self.ffn in ("swiglu", "geglu") else 1
                if self.is_moe and kind in ("attn", "local"):
                    n += rep * (D * self.n_experts +
                                self.n_experts * (fmul * D * F + F * D))
                else:
                    n += rep * (fmul * D * F + F * D)
        return n

    def active_param_count(self) -> int:
        """MoE: only top_k of n_experts active per token."""
        if not self.is_moe:
            return self.param_count()
        D, F = self.d_model, self.d_ff
        fmul = 2 if self.ffn in ("swiglu", "geglu") else 1
        dead = (self.n_experts - self.top_k) * (fmul * D * F + F * D)
        return self.param_count() - self.n_layers * dead

    def flops_per_token(self) -> float:
        """~6·N_active per trained token (fwd+bwd)."""
        return 6.0 * self.active_param_count()

    def reduced(self, **over) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2 * len(self.layer_pattern)),
            d_model=64,
            n_heads=max(2, min(4, self.n_heads)),
            n_kv_heads=1 if self.n_kv_heads < self.n_heads else 2,
            head_dim=16,
            d_ff=128 if not self.is_moe else 32,
            vocab=512,
            n_experts=min(self.n_experts, 8),
            top_k=min(self.top_k, 2),
            # capacity ≥ tokens in smoke tests → decode ≡ full forward exactly
            capacity_factor=max(self.capacity_factor, 8.0),
            attn_window=min(self.attn_window, 32) if self.attn_window else None,
            lru_width=64 if self.lru_width else None,
            rwkv_head_size=16,
            rwkv_decay_lora=8,
            attn_block_k=32,
            remat=False,
            act_dtype=jnp.float32,
        )
        if kw["n_kv_heads"] > kw["n_heads"]:
            kw["n_kv_heads"] = kw["n_heads"]
        if self.n_kv_heads == self.n_heads:   # MHA stays MHA
            kw["n_kv_heads"] = kw["n_heads"]
        if self.mrope_sections is not None:   # rescale to the reduced head
            half = kw["head_dim"] // 2
            t = max(1, half // 4)
            h = (half - t) // 2
            kw["mrope_sections"] = (t, h, half - t - h)
        kw.update(over)
        return dataclasses.replace(self, **kw)


# ----------------------------------------------------------------------
# Assigned input shapes (identical set for every LM arch)
# ----------------------------------------------------------------------

SHAPES = {
    "train_4k":    dict(seq_len=4_096, global_batch=256, step="train"),
    "prefill_32k": dict(seq_len=32_768, global_batch=32, step="prefill"),
    "decode_32k":  dict(seq_len=32_768, global_batch=128, step="decode"),
    "long_500k":   dict(seq_len=524_288, global_batch=1, step="decode"),
}

# archs allowed to run long_500k (sub-quadratic sequence mixing)
SUBQUADRATIC = {"rwkv6-1.6b", "recurrentgemma-2b", "gemma3-1b"}


def cell_is_runnable(arch_name: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_name in SUBQUADRATIC
    return True


def input_specs(cfg: ModelConfig, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of the cell.

    Weak-type-correct, shardable, no device allocation (dry-run contract).
    Returns (step_kind, {name: ShapeDtypeStruct})."""
    import jax
    import numpy as np

    sh = SHAPES[shape_name]
    B, S, step = sh["global_batch"], sh["seq_len"], sh["step"]
    T = S if step in ("train", "prefill") else 1
    i32 = jnp.int32

    def tok(t):
        return jax.ShapeDtypeStruct((B, t), i32)

    specs = {}
    if cfg.embed_inputs:
        specs["tokens"] = tok(T)
    else:  # frontend stub: precomputed frame/patch embeddings
        specs["embeds"] = jax.ShapeDtypeStruct((B, T, cfg.d_model),
                                               cfg.act_dtype)
    if cfg.mrope_sections is not None:
        specs["positions"] = jax.ShapeDtypeStruct((3, B, T), i32)
    if step == "train":
        specs["labels"] = tok(T)
        specs["mask"] = jax.ShapeDtypeStruct((B, T), jnp.float32)
    return step, specs
