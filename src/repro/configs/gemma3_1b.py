"""gemma3-1b [dense]: 26L d1152 4H (MQA kv=1, head_dim 256) ff6912 GeGLU
vocab 262144, 5:1 local(512):global [hf:google/gemma-3-1b-pt]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab=262_144, ffn="geglu",
    layer_pattern=("local", "local", "local", "local", "local", "attn"),
    attn_window=512,
    rope_theta=1_000_000.0, tie_embeddings=True, embed_scale=True,
)
