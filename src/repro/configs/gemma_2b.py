"""gemma-2b [dense]: 18L d2048 8H (MQA kv=1, head_dim 256) ff16384 GeGLU
vocab 256000 [arXiv:2403.08295]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=256_000, ffn="geglu",
    rope_theta=10_000.0, tie_embeddings=True, embed_scale=True,
)
