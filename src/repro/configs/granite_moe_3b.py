"""granite-moe-3b-a800m [moe]: 32L d1536 24H (GQA kv=8) per-expert ff512
vocab 49155, 40 experts top-8 [hf:ibm-granite]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab=49_155, ffn="swiglu",
    n_experts=40, top_k=8,
    rope_theta=10_000.0, tie_embeddings=True,
)
