"""musicgen-large [audio]: 48L d2048 32H ff8192 vocab 2048 — decoder-only
over EnCodec tokens [arXiv:2306.05284].  The EnCodec frontend is a STUB per
the assignment: input_specs feeds precomputed frame embeddings [B, T, D]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab=2048, ffn="gelu", norm="layernorm",
    rope_theta=10_000.0, tie_embeddings=False, embed_inputs=False,
)
