"""The paper's own workload: log-quantized CNN inference on the NeuroMAX
grid.  Not one of the 10 assigned LM architectures — this config drives the
faithful-reproduction benchmarks (Figs 17/19/20, Tables 2/3) and the CNN
training example.
"""

from __future__ import annotations

import dataclasses

from ..core.logquant import LogQuantConfig


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str = "neuromax-cnn"
    network: str = "vgg16"          # vgg16|mobilenet_v1|resnet34|squeezenet
    img: int = 224
    n_classes: int = 1000
    cin: int = 3
    width_mult: float = 1.0
    quant: str | None = "logq6"     # paper numerics by default
    qcfg: LogQuantConfig = LogQuantConfig()

    def reduced(self, **over):
        kw = dict(img=32, n_classes=10, width_mult=0.125)
        kw.update(over)
        return dataclasses.replace(self, **kw)


CONFIG = CNNConfig()
