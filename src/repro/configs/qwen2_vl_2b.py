"""qwen2-vl-2b [vlm]: 28L d1536 12H (GQA kv=2) ff8960 vocab 151936, M-RoPE
(t/h/w sections 16/24/24 of head_dim/2=64) [arXiv:2409.12191].  The ViT
frontend is a STUB: input_specs feeds merged patch/text embeddings plus
[3, B, T] M-RoPE position ids."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab=151_936, ffn="swiglu", qkv_bias=True,
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0, tie_embeddings=True, embed_inputs=False,
)
