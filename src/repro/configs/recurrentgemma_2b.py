"""recurrentgemma-2b [hybrid]: 26L d2560 10H (MQA kv=1, head_dim 256)
ff7680 GeGLU vocab 256000 — RG-LRU + local attention (2048), pattern
(rec, rec, attn) [arXiv:2402.19427]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab=256_000, ffn="geglu",
    layer_pattern=("rec", "rec", "local"), attn_window=2048,
    lru_width=2560, conv1d_width=4,
    rope_theta=10_000.0, tie_embeddings=True, embed_scale=True,
)
