"""Architecture registry: --arch <id> resolution for every driver."""
from . import (gemma_2b, gemma3_1b, granite_moe_1b, granite_moe_3b,
               llama3_405b, musicgen_large, qwen15_4b, qwen2_vl_2b,
               recurrentgemma_2b, rwkv6_16b)

REGISTRY = {m.CONFIG.name: m.CONFIG for m in (
    gemma_2b, llama3_405b, gemma3_1b, qwen15_4b, musicgen_large,
    qwen2_vl_2b, granite_moe_3b, granite_moe_1b, rwkv6_16b,
    recurrentgemma_2b)}

ARCH_NAMES = tuple(REGISTRY)


def get_config(name: str):
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]
