"""rwkv6-1.6b [ssm]: 24L d2048 (attention-free, head_size 64) cmix ff7168
vocab 65536 — Finch data-dependent decay [arXiv:2404.05892]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=7168, vocab=65_536, ffn="gelu", norm="layernorm",
    layer_pattern=("rwkv",), rwkv_head_size=64,
    tie_embeddings=False,
)
