"""NeuroMAX core: log quantization, log-PE math, PE-grid + dataflow models."""

from .logquant import (LogQuantConfig, QuantizedTensor, fake_log_quant,
                       linear_quantize, log_dequantize, log_quantize,
                       quantize_tensor)
from .logmath import LogPEThread, log_product_fixed, make_frac_lut
from .dataflow import (CLOCK_HZ, PEAK_GOPS_PAPER, LayerSpec, LayerPerf,
                       NetworkPerf, analyze_layer, analyze_network)
from .pe_grid import PEGrid, GridStats, TOTAL_THREADS
from . import accelerator, cost_model
