"""Whole-CNN walk of the NeuroMAX accelerator model (§6, Figs. 19/20, Tables 2/3).

Defines the layer graphs of the CNNs the paper benchmarks (VGG-16,
MobileNet v1, ResNet-34; plus SqueezeNet for the Fig-1 nets) and runs them
through the analytical dataflow model in `core/dataflow.py`.
"""

from __future__ import annotations

from .dataflow import LayerSpec, NetworkPerf, analyze_network

__all__ = ["vgg16_layers", "mobilenet_v1_layers", "resnet34_layers",
           "squeezenet_layers", "run_network", "NETWORKS"]


def vgg16_layers(img: int = 224) -> list:
    """The 13 conv layers of VGG-16 (pad 1, stride 1, 3×3)."""
    cfg = [  # (name, C_in, C_out, spatial)
        ("CONV1_1", 3, 64, img), ("CONV1_2", 64, 64, img),
        ("CONV2_1", 64, 128, img // 2), ("CONV2_2", 128, 128, img // 2),
        ("CONV3_1", 128, 256, img // 4), ("CONV3_2", 256, 256, img // 4),
        ("CONV3_3", 256, 256, img // 4),
        ("CONV4_1", 256, 512, img // 8), ("CONV4_2", 512, 512, img // 8),
        ("CONV4_3", 512, 512, img // 8),
        ("CONV5_1", 512, 512, img // 16), ("CONV5_2", 512, 512, img // 16),
        ("CONV5_3", 512, 512, img // 16),
    ]
    return [LayerSpec(n, "conv", s, s, c, p, K=3, stride=1, pad=1)
            for n, c, p, s in cfg]


def mobilenet_v1_layers(img: int = 224) -> list:
    """MobileNet v1: first full conv then 13 dw/pw pairs."""
    layers = [LayerSpec("CONV1", "conv", img, img, 3, 32, K=3, stride=2, pad=1)]
    # (C_in, C_out, stride) for each dw/pw pair
    pairs = [(32, 64, 1), (64, 128, 2), (128, 128, 1), (128, 256, 2),
             (256, 256, 1), (256, 512, 2)] + [(512, 512, 1)] * 5 + \
            [(512, 1024, 2), (1024, 1024, 1)]
    s = img // 2
    for i, (cin, cout, st) in enumerate(pairs):
        layers.append(LayerSpec(f"DW{i+1}", "dwconv", s, s, cin, cin,
                                K=3, stride=st, pad=1))
        s_out = s // st
        layers.append(LayerSpec(f"PW{i+1}", "pwconv", s_out, s_out, cin, cout, K=1))
        s = s_out
    return layers


def resnet34_layers(img: int = 224) -> list:
    """ResNet-34 conv layers (7×7 stem approximated as the paper does by the
    grid's multi-cycle higher-order path; basic blocks are 3×3)."""
    layers = [LayerSpec("CONV1", "conv", img, img, 3, 64, K=5, stride=2, pad=2)]
    s = img // 4  # after stem stride-2 conv + stride-2 maxpool
    stages = [(64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)]
    cin = 64
    for si, (c, blocks, first_stride) in enumerate(stages):
        for b in range(blocks):
            st = first_stride if b == 0 else 1
            layers.append(LayerSpec(f"S{si+1}B{b+1}a", "conv", s, s, cin, c,
                                    K=3, stride=st, pad=1))
            s = s // st
            layers.append(LayerSpec(f"S{si+1}B{b+1}b", "conv", s, s, c, c,
                                    K=3, stride=1, pad=1))
            if b == 0 and (st != 1 or cin != c):  # projection shortcut
                layers.append(LayerSpec(f"S{si+1}B{b+1}p", "pwconv",
                                        s * st, s * st, cin, c, K=1, stride=st))
            cin = c
    return layers


def squeezenet_layers(img: int = 224) -> list:
    """SqueezeNet v1.0 fire modules (squeeze 1×1, expand 1×1 + 3×3)."""
    layers = [LayerSpec("CONV1", "conv", img, img, 3, 96, K=5, stride=2, pad=2)]
    s = img // 4
    fires = [(96, 16, 64), (128, 16, 64), (128, 32, 128),
             (256, 32, 128), (256, 48, 192), (384, 48, 192),
             (384, 64, 256), (512, 64, 256)]
    pool_after = {0: None}
    for i, (cin, sq, ex) in enumerate(fires):
        if i == 3:
            s //= 2
        if i == 7:
            s //= 2
        layers.append(LayerSpec(f"F{i+2}s", "pwconv", s, s, cin, sq, K=1))
        layers.append(LayerSpec(f"F{i+2}e1", "pwconv", s, s, sq, ex, K=1))
        layers.append(LayerSpec(f"F{i+2}e3", "conv", s, s, sq, ex,
                                K=3, stride=1, pad=1))
    layers.append(LayerSpec("CONV10", "pwconv", s, s, 512, 1000, K=1))
    return layers


NETWORKS = {
    "vgg16": vgg16_layers,
    "mobilenet_v1": mobilenet_v1_layers,
    "resnet34": resnet34_layers,
    "squeezenet": squeezenet_layers,
}


def run_network(name: str, img: int = 224) -> NetworkPerf:
    return analyze_network(name, NETWORKS[name](img))
