"""Area/cost model of the multi-threaded log PE (Fig. 17, Table 1/2).

The paper's measurement at 16-bit output precision: a log PE with 3 threads
costs 1.05× the LUTs and 1.14× the FFs of one area-optimised linear
(multiplier) PE.  A single log thread (barrel shifter + 2-entry LUT + adder)
is therefore ≈0.35×/0.38× of a linear PE — which is exactly the "spend the
multiplier area on 3 threads" trade the paper makes.

We expose the model so benchmarks can regenerate Fig 17, the 122
cost-adjusted PE count, and the Table-2 peak-throughput-per-PE comparison.
"""

from __future__ import annotations

import dataclasses
import math

# Anchors from the paper (Zynq-7020, 16-bit output precision)
LINEAR_PE_LUT = 580.0   # area-optimised 16-bit multiplier PE (relative anchor)
LINEAR_PE_FF = 320.0
LUT_RATIO_3T = 1.05     # log(3) / linear, Fig. 17
FF_RATIO_3T = 1.14
N_PES = 108
N_THREADS = 3
TOTAL_ACCEL_LUTS = 20680   # Table 1
TOTAL_ACCEL_FFS = 17207
TOTAL_BRAMS = 108
POWER_W = 2.727


@dataclasses.dataclass(frozen=True)
class PECost:
    luts: float
    ffs: float

    def relative_to_linear(self):
        return self.luts / LINEAR_PE_LUT, self.ffs / LINEAR_PE_FF


def log_pe_cost(threads: int) -> PECost:
    """Linear-in-threads model anchored at the paper's 3-thread point.

    Fig 17 shows near-zero fixed overhead: cost(3 threads) = 3 · cost(1),
    so per-thread LUTs = (1.05/3)·linear and FFs = (1.14/3)·linear."""
    lut_per_thread = LUT_RATIO_3T / N_THREADS * LINEAR_PE_LUT
    ff_per_thread = FF_RATIO_3T / N_THREADS * LINEAR_PE_FF
    return PECost(luts=threads * lut_per_thread, ffs=threads * ff_per_thread)


def linear_pe_cost() -> PECost:
    return PECost(luts=LINEAR_PE_LUT, ffs=LINEAR_PE_FF)


# Table 2: "a total of 108 linear PEs would be equivalent, in cost, to ≈122
# multi-threaded log PEs" → the paper's blended cost ratio:
COST_ADJUST_RATIO = 122.0 / 108.0  # ≈1.13, between the 1.05 LUT / 1.14 FF ratios


def cost_adjusted_pe_count(n_pes: int = N_PES, threads: int = N_THREADS) -> int:
    """Table 2's '122 (adjusted)': linear-PE cost units the log grid spends.

    Anchored on the paper's stated 108↔122 equivalence; the LUT/FF blend
    (1.05, 1.14) brackets the implied 1.13 ratio."""
    if threads == N_THREADS:
        return math.ceil(n_pes * COST_ADJUST_RATIO)
    lut_r, ff_r = log_pe_cost(threads).relative_to_linear()
    return math.ceil(n_pes * (lut_r + ff_r) / 2.0)


def peak_throughput_per_pe(threads: int = N_THREADS, adjusted: bool = True,
                           n_pes: int = N_PES) -> float:
    """Peak-throughput-per-PE ratio (linear single-core PE ≡ 1.0).

    Each thread sustains one MAC/cycle, so the raw ratio is `threads`; the
    cost-adjusted ratio divides by the relative area (Table 2: 2.7)."""
    total = n_pes * threads
    denom = cost_adjusted_pe_count(n_pes, threads) if adjusted else n_pes
    return total / denom


def area_overhead_vs_linear(threads: int = N_THREADS) -> float:
    """The abstract's '6 % area overhead' = blended (LUT,FF) ratio − 1."""
    lut_r, ff_r = log_pe_cost(threads).relative_to_linear()
    # paper's abstract quotes the LUT-dominated figure (~5-6 %)
    return (lut_r + ff_r) / 2.0 - 1.0


def breakdown():
    """Fig-18-style resource breakdown (fractions from the paper)."""
    return {
        "luts": {"pe_grid+adder_net0": 0.81, "adder_net1+accum": 0.09,
                 "state_controller": 0.06, "post_processing": 0.01,
                 "other": 0.03},
        "ffs": {"pe_grid+adder_net0": 0.91, "adder_net1+accum": 0.04,
                "state_controller": 0.03, "post_processing": 0.01,
                "other": 0.01},
        "power": {"processing_system": 0.57, "pe_grid+adder_net0": 0.26,
                  "brams": 0.10, "other": 0.07},
    }
