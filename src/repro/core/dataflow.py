"""Analytical model of the 2D weight-broadcast dataflow (§5, Figs. 6-16).

`core/pe_grid.py` executes the dataflow; this module *counts* it — cycles,
thread utilization, psum-storage fraction and DDR traffic for arbitrary layer
shapes — fast enough to walk whole CNNs (Fig. 19/20, Tables 2/3).

Derivation (verified against the paper's own worked examples):

3×3, stride s (§5.1):  a 6-row band × 3-col window slides one column per
cycle → positions = ceil((W' - 2) / s) cycles per band, bands = ceil(H'/6),
one input channel per PE matrix (6 in flight), one filter per pass:
    cycles = ceil(C/6) · P · bands · positions
Paper example 12×6 input, s=1: 2 bands × 4 positions = 8 cycles, 360 MACs
→ 45 OPS/cycle = 83.3 % of one matrix's 54 threads, 3/18 psums stored.

1×1 (§5.2):  3 channels per PE (one per thread), 18 pixel slots per matrix,
18 channels in flight across 6 matrices, channel accumulation at net-1:
    cycles = ceil(HW/18) · P · ceil(C/18)
Paper example 6×6×6 × (1×1×6 ×6): 2 pixel tiles × 6 filters = 12 cycles,
1296 MACs → 108 OPS/cycle = 100 % of the two active matrices.

K∈{4,5} (§5.3): width > 3 needs ceil(K/3) column loads per position
(Fig. 14), outputs assembled from old+new psums (eqs. 9-10).

Depthwise 3×3: one filter per channel → the P factor collapses to 1.
"""

from __future__ import annotations

import dataclasses
import math

from .pe_grid import N_MATRICES, PE_COLS, PE_ROWS, THREADS, TOTAL_THREADS

CLOCK_HZ = 200e6                       # Zynq-7020 processing clock
PEAK_OPS_PER_CYCLE = TOTAL_THREADS     # 324 (1 MAC = 1 OP, §5.1 accounting)
PEAK_GOPS_PAPER = 324.0                # Table-2 accounting: util × 324 GOPS


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One CNN layer as the accelerator sees it."""
    name: str
    kind: str          # conv | dwconv | pwconv (1x1) | pool
    H: int             # input height
    W: int             # input width
    C: int             # input channels
    P: int             # output channels (== C for dwconv/pool)
    K: int = 3         # kernel size
    stride: int = 1
    pad: int = 0

    @property
    def Ho(self) -> int:
        return (self.H + 2 * self.pad - self.K) // self.stride + 1

    @property
    def Wo(self) -> int:
        return (self.W + 2 * self.pad - self.K) // self.stride + 1

    @property
    def macs(self) -> int:
        per_out = self.K * self.K * (1 if self.kind in ("dwconv", "pool") else self.C)
        return self.Ho * self.Wo * self.P * per_out


@dataclasses.dataclass
class LayerPerf:
    spec: LayerSpec
    cycles: int
    useful_macs: int
    stored_psum_frac: float
    ddr_bytes_log: int     # 7-bit codes (6+sign), weights+ifmap+ofmap
    ddr_bytes_fp16: int    # 16-bit baseline for the same traffic

    @property
    def utilization(self) -> float:
        return self.useful_macs / (self.cycles * PEAK_OPS_PER_CYCLE)

    @property
    def latency_ms(self) -> float:
        return self.cycles / CLOCK_HZ * 1e3

    @property
    def gops_paper(self) -> float:
        """Table-2 accounting (throughput = utilization × 324 GOPS)."""
        return self.utilization * PEAK_GOPS_PAPER

    @property
    def gmacs_per_s(self) -> float:
        return self.useful_macs / (self.cycles / CLOCK_HZ) / 1e9


def _traffic(spec: LayerSpec) -> tuple[int, int]:
    """DDR bytes moved for the layer (no psum traffic — §4.1: all psums stay
    on-chip).  ifmap + weights + ofmap, once each (weight/input reuse in SRAM)."""
    n_in = spec.H * spec.W * spec.C
    n_w = spec.K * spec.K * (1 if spec.kind in ("dwconv", "pool") else spec.C) * spec.P
    n_out = spec.Ho * spec.Wo * spec.P
    bits_log = 7 * (n_in + n_out) + 7 * n_w        # 6-bit log + sign
    bits_fp16 = 16 * (n_in + n_out + n_w)
    return (bits_log + 7) // 8, (bits_fp16 + 7) // 8


def analyze_layer(spec: LayerSpec) -> LayerPerf:
    Hp = spec.H + 2 * spec.pad
    Wp = spec.W + 2 * spec.pad
    if spec.kind == "pwconv" or spec.K == 1:
        pix_tiles = math.ceil(spec.H * spec.W / (PE_ROWS * PE_COLS))
        cgroups = math.ceil(spec.C / (N_MATRICES * THREADS))
        cycles = pix_tiles * spec.P * cgroups
        stored_frac = 0.0
    elif spec.kind == "dwconv":
        bands = spec.Ho * spec.stride / PE_ROWS  # streamed (VAR-len SR)
        positions = spec.Wo
        cycles = math.ceil(math.ceil(spec.C / N_MATRICES) * bands * positions)
        stored_frac = 3.0 / 18.0
    elif spec.kind == "pool":
        # pooling reuses the conv path with the chosen stride/kernel (§5.3)
        bands = spec.Ho * spec.stride / PE_ROWS
        positions = spec.Wo
        cycles = math.ceil(math.ceil(spec.C / N_MATRICES) * bands * positions)
        stored_frac = 0.0
    else:  # standard conv, K in {3, 4, 5}
        col_loads = math.ceil(spec.K / PE_COLS)
        # Bands stream row-continuously: the boundary psums ride the VAR-len
        # shift registers, so band count is fractional Ho·s/6 (each band pass
        # yields 6/s output rows).  This reproduces the paper's Table-3
        # per-layer latencies to ≤2 % (except conv1_1 — see EXPERIMENTS.md).
        bands = spec.Ho * spec.stride / PE_ROWS
        positions = spec.Wo
        cycles = math.ceil(math.ceil(spec.C / N_MATRICES) * spec.P
                           * bands * positions * col_loads)
        stored_frac = 3.0 / 18.0 if spec.K == 3 else 5.0 / 18.0
    d_log, d_fp16 = _traffic(spec)
    return LayerPerf(spec=spec, cycles=int(cycles), useful_macs=spec.macs,
                     stored_psum_frac=stored_frac,
                     ddr_bytes_log=d_log, ddr_bytes_fp16=d_fp16)


@dataclasses.dataclass
class NetworkPerf:
    name: str
    layers: list

    @property
    def total_cycles(self) -> int:
        return sum(l.cycles for l in self.layers)

    @property
    def total_macs(self) -> int:
        return sum(l.useful_macs for l in self.layers)

    @property
    def avg_utilization(self) -> float:
        """Cycle-weighted average utilization (what throughput realises)."""
        c = self.total_cycles
        return self.total_macs / (c * PEAK_OPS_PER_CYCLE) if c else 0.0

    @property
    def mean_layer_utilization(self) -> float:
        """Unweighted per-layer mean (Fig-19 'average utilization')."""
        ls = [l.utilization for l in self.layers]
        return sum(ls) / len(ls) if ls else 0.0

    @property
    def latency_ms(self) -> float:
        return self.total_cycles / CLOCK_HZ * 1e3

    @property
    def throughput_gops_paper(self) -> float:
        """Fig 20 accounting: (unweighted per-layer mean util) × 324 GOPS —
        this is exactly how the paper's 307.8/281.8/268.9 figures decompose."""
        return self.mean_layer_utilization * PEAK_GOPS_PAPER

    @property
    def ddr_bytes_log(self) -> int:
        return sum(l.ddr_bytes_log for l in self.layers)

    @property
    def ddr_bytes_fp16(self) -> int:
        return sum(l.ddr_bytes_fp16 for l in self.layers)


def analyze_network(name: str, specs: list) -> NetworkPerf:
    return NetworkPerf(name=name, layers=[analyze_layer(s) for s in specs])
