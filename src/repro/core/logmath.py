"""Bit-exact fixed-point semantics of the NeuroMAX log-PE compute thread.

Implements eqs. (5)-(8):

    w_q · a_q = sign(w_q) · 2^(g'),        g' = w' + a'            (5,6)
              = sign(w_q) · 2^INT(g) · 2^FRAC(g),  g = g'/2^n      (7)
              = sign(w_q) · (LUT(FRAC(g')) >> ¬INT(g'))            (8)

where w', a' are integer log codes in 1/2^n-octave units.  The LUT holds the
2^n fractional powers 2^(f/2^n) as fixed-point integers with F fractional
bits; the shift realises the integer part of the exponent.  This module is
the *oracle* for the hardware: `tests/test_logmath.py` proves the LUT+shift
path equals the closed form, and `core/pe_grid.py` uses it so the grid model
computes exactly what the RTL would.

Everything here is plain numpy on small ints — it models hardware words, not
tensors (the vectorised tensor path lives in `core/logquant.py` and
`kernels/log_matmul.py`).
"""

from __future__ import annotations

import numpy as np

__all__ = ["LogPEThread", "log_product_fixed", "log_product_fixed_batch",
           "make_frac_lut"]


def make_frac_lut(frac_bits: int, out_frac_bits: int) -> np.ndarray:
    """The pre-computed fractional table stored in each thread (2^n entries).

    Paper: "we have n = 1 and thus store 2^n = 2 values in the thread memory."
    Entry f holds round(2^(f / 2^n) · 2^F) for f in [0, 2^n).
    """
    steps = 1 << frac_bits
    return np.array(
        [int(round((2.0 ** (f / steps)) * (1 << out_frac_bits))) for f in range(steps)],
        dtype=np.int64,
    )


def log_product_fixed(w_code: int, a_code: int, w_sign: int,
                      frac_bits: int = 1, out_frac_bits: int = 12) -> int:
    """Eq. (8): one thread's product as a fixed-point integer (F frac bits).

    w_code, a_code : integer log codes in 1/2^n-octave units (may be negative)
    w_sign         : ±1 (the paper's w'[6]; activations are post-ReLU ≥ 0)
    returns        : integer v such that the real value is v / 2^F
    """
    steps = 1 << frac_bits
    lut = make_frac_lut(frac_bits, out_frac_bits)
    g = int(w_code) + int(a_code)                       # eq. (6), integer add
    int_part = g >> frac_bits                           # floor(g / 2^n)
    frac_part = g & (steps - 1)                         # g mod 2^n  (≥ 0)
    v = int(lut[frac_part])
    if int_part >= 0:
        v <<= int_part                                  # 2^INT, left shift
    else:
        v >>= -int_part                                 # ">> ¬INT" of eq. (8)
    return int(w_sign) * v


def log_product_fixed_batch(w_codes, a_codes, w_signs=1, a_nonzero=True,
                            w_nonzero=True, frac_bits: int = 1,
                            out_frac_bits: int = 12,
                            lut: np.ndarray | None = None) -> np.ndarray:
    """Eq. (8) over whole arrays at once — the same LUT+barrel-shift per
    element as `log_product_fixed`, broadcast with numpy int64 ops.

    This is what lets `core.pe_grid.PEGrid` model every thread of a cycle
    (or a whole channel group of cycles) in one call instead of 10⁴+ Python
    calls.  Bit-identical to the scalar path whenever the shifted product
    fits int64, i.e. INT(g) ≤ 62 − (F+1) for a 2^(F+1)-bounded LUT value
    (the scalar path promotes to unbounded Python ints); any ⟨6,1⟩
    quantizer emits codes ≤ 0, so every grid use is in range.
    """
    steps = 1 << frac_bits
    if lut is None:
        lut = make_frac_lut(frac_bits, out_frac_bits)
    g = np.asarray(w_codes, np.int64) + np.asarray(a_codes, np.int64)
    int_part = g >> frac_bits
    frac_part = g & (steps - 1)
    v = lut[frac_part]
    # one of the two shifts is always by 0; clip keeps numpy's shift defined
    # (LUT values < 2^(F+1), so a >=63-bit right shift is exactly 0 anyway)
    v = (v << np.clip(int_part, 0, 62)) >> np.clip(-int_part, 0, 62)
    out = np.asarray(w_signs, np.int64) * v
    mask = np.logical_and(a_nonzero, w_nonzero)
    return np.where(mask, out, 0)


class LogPEThread:
    """One compute thread of a PE (Fig. 3a): code adder + LUT + barrel shift."""

    def __init__(self, frac_bits: int = 1, out_frac_bits: int = 12):
        self.frac_bits = frac_bits
        self.out_frac_bits = out_frac_bits
        self.lut = make_frac_lut(frac_bits, out_frac_bits)

    def __call__(self, w_code, a_code, w_sign=1, a_nonzero=True, w_nonzero=True):
        if not (a_nonzero and w_nonzero):
            return 0
        return log_product_fixed(w_code, a_code, w_sign,
                                 self.frac_bits, self.out_frac_bits)

    def batch(self, w_codes, a_codes, w_signs=1, a_nonzero=True,
              w_nonzero=True) -> np.ndarray:
        """Vectorised `__call__` over broadcastable arrays (shared LUT)."""
        return log_product_fixed_batch(w_codes, a_codes, w_signs, a_nonzero,
                                       w_nonzero, self.frac_bits,
                                       self.out_frac_bits, lut=self.lut)

    def to_float(self, v: int) -> float:
        return v / float(1 << self.out_frac_bits)

    def closed_form(self, w_code, a_code, w_sign=1) -> float:
        """sign · 2^((w'+a')/2^n) — what eq. (5) says the product should be."""
        steps = 1 << self.frac_bits
        return w_sign * 2.0 ** ((w_code + a_code) / steps)
