"""Base-√2 logarithmic quantization (NeuroMAX §3, eqs. 1-4).

A log quantizer with parameters ⟨m, n, b⟩ maps x → x' = round(log_b |x|),
clipped to a signed Qm.n range.  For b = 2^(1/2^n) (n = 1 → b = √2) a code is
an integer count of 1/2^n octaves, i.e. log2 with `n` fractional bits.  This
is exactly what makes the hardware cheap: the product of two codes is an
integer add, and 2^(code/2^n) decomposes into a 2^n-entry LUT times a shift
(eq. 8) — see `core/logmath.py` for the bit-exact fixed-point semantics.

Storage layout (matches the paper's w'[6]-is-sign convention):
    packed int8 = (sign << bits) | biased_code,   biased_code ∈ [0, 2^bits)
with a per-channel (or per-tensor) fp scale so the largest magnitude maps to
the top code.  Exact zeros get the *smallest* magnitude code with sign 0 and a
dedicated zero flag folded in: we reserve biased code 0 as "zero" (the paper
special-cases x = 0 in eq. 4).

Also includes the linear Qm.n quantizer (eqs. 1-2) used for the Fig-1
comparison, and a straight-through-estimator fake-quant for training.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "LogQuantConfig",
    "log_quantize",
    "log_dequantize",
    "fake_log_quant",
    "linear_quantize",
    "quantize_tensor",
    "dequantize_tensor",
    "QuantizedTensor",
]


@dataclasses.dataclass(frozen=True)
class LogQuantConfig:
    """⟨m, n, b⟩ of the paper, expressed in bits.

    bits:       exponent-code width (signed range, excludes the sign bit).
                Paper uses 6 ("6-bit log" in Table 2, +1 sign bit on weights).
    frac_bits:  n — fractional bits of the log2 exponent. n=1 → base √2,
                n=0 → base 2. steps-per-octave = 2^n. LUT size = 2^n.
    per_channel: quantize with one scale per trailing channel (axis -1 of the
                canonical [in, out] weight layout) instead of per tensor.
    """

    bits: int = 6
    frac_bits: int = 1
    per_channel: bool = True

    @property
    def steps(self) -> int:  # steps per octave
        return 1 << self.frac_bits

    @property
    def base(self) -> float:
        return float(2.0 ** (1.0 / self.steps))

    @property
    def code_min(self) -> int:
        # biased code 0 is reserved for exact zero; magnitude codes occupy
        # [1, 2^bits - 1], representing unbiased [cmin, 0] with 0 ↦ top code.
        return -((1 << self.bits) - 2)

    @property
    def code_max(self) -> int:
        return 0  # after max-abs normalisation, log2(|x|/scale) ≤ 0

    @property
    def zero_code(self) -> int:
        return 0  # biased

    @property
    def bias(self) -> int:
        # biased = unbiased + bias; unbiased cmin ↦ 1, 0 ↦ 2^bits - 1
        return (1 << self.bits) - 1

    @property
    def storage_bits(self) -> int:
        return self.bits + 1  # + sign

    @property
    def bytes_per_weight(self) -> float:
        return self.storage_bits / 8.0


DEFAULT = LogQuantConfig()


def _scale_for(x: jnp.ndarray, cfg: LogQuantConfig, axis=None):
    a = jnp.abs(x)
    if axis is None:
        s = jnp.max(a)
    else:
        s = jnp.max(a, axis=axis, keepdims=True)
    # avoid log(0); an all-zero tensor/channel quantizes to all-zero codes.
    return jnp.where(s > 0, s, jnp.ones_like(s))


def log_quantize(x: jnp.ndarray, cfg: LogQuantConfig = DEFAULT, scale=None):
    """x → (packed int8 codes, scale).  packed = (sign << bits) | biased_code."""
    if scale is None:
        axis = tuple(range(x.ndim - 1)) if (cfg.per_channel and x.ndim >= 2) else None
        scale = _scale_for(x, cfg, axis)
    mag = jnp.abs(x) / scale
    # log2 with frac_bits of precision; round-to-nearest on the half-step grid
    code = jnp.round(jnp.log2(jnp.maximum(mag, 1e-38)) * cfg.steps)
    code = jnp.clip(code, cfg.code_min, cfg.code_max)
    biased = code.astype(jnp.int32) + cfg.bias
    biased = jnp.where(x == 0, cfg.zero_code, biased)
    sign = (x < 0).astype(jnp.int32)
    packed = (sign << cfg.bits) | biased
    return packed.astype(jnp.int8), scale


def unpack(packed: jnp.ndarray, cfg: LogQuantConfig = DEFAULT):
    """packed int8 → (unbiased code int32, sign ±1, nonzero mask)."""
    p = packed.astype(jnp.int32)
    biased = p & ((1 << cfg.bits) - 1)
    sign = 1 - 2 * ((p >> cfg.bits) & 1)
    nonzero = biased != cfg.zero_code
    code = biased - cfg.bias
    return code, sign, nonzero


def log_dequantize(packed: jnp.ndarray, scale, cfg: LogQuantConfig = DEFAULT,
                   dtype=jnp.float32):
    """Vectorised eq. (8): sign · LUT(FRAC) · 2^INT  ≡  sign · 2^(code/steps)."""
    code, sign, nonzero = unpack(packed, cfg)
    mag = jnp.exp2(code.astype(dtype) / cfg.steps)
    out = sign.astype(dtype) * jnp.where(nonzero, mag, 0.0)
    return (out * scale).astype(dtype)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def fake_log_quant(x, cfg: LogQuantConfig = DEFAULT):
    """Quantize-dequantize with straight-through gradients (for QAT)."""
    packed, scale = log_quantize(x, cfg)
    return log_dequantize(packed, scale, cfg, dtype=x.dtype)


def _fq_fwd(x, cfg):
    return fake_log_quant(x, cfg), None


def _fq_bwd(cfg, _, g):
    return (g,)  # straight-through


fake_log_quant.defvjp(_fq_fwd, _fq_bwd)


def linear_quantize(x: jnp.ndarray, int_bits: int, frac_bits: int):
    """Linear Qm.n quantizer, eqs. (1)-(2), for the Fig-1 comparison."""
    eps = 2.0 ** (-frac_bits)
    lo, hi = -(2.0 ** (int_bits - 1)), 2.0 ** (int_bits - 1) - eps
    return jnp.clip(jnp.round(x / eps) * eps, lo, hi)


# ---------------------------------------------------------------------------
# Pytree container for a quantized parameter, used by serving / kernels.
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class QuantizedTensor:
    """A log-quantized array: int8 packed codes + fp scale (+ static cfg).

    ``layout`` is a storage hint for consumers: ``None`` means ``packed``
    has the natural layout of ``shape``; ``"conv_taps"`` means a conv
    kernel pre-reshaped to tap-major ``[K*K, Cin_g, Cout]`` at load time
    (what the fused Pallas conv kernel streams); ``"lane_packed"`` means a
    grouped-conv kernel pre-arranged into 128-lane superblocks
    ``[n_sb, K*K, G_b*cin_lane, Cout//groups]`` with ``layout_meta =
    (G_b, cin_lane, groups)`` carrying the group-to-lane map (see
    `kernels/log_conv2d.lane_pack_codes`).  `ops.conv2d` accepts all
    three.
    """

    def __init__(self, packed, scale, cfg: LogQuantConfig = DEFAULT,
                 shape=None, layout: str | None = None,
                 layout_meta: tuple | None = None):
        self.packed = packed
        self.scale = scale
        self.cfg = cfg
        self.shape = shape if shape is not None else packed.shape
        self.layout = layout
        self.layout_meta = layout_meta

    def dequantize(self, dtype=jnp.bfloat16):
        if self.layout == "lane_packed":
            # layout transforms live with the kernels; import lazily so
            # core stays import-light (no cycle: kernels import core at
            # module scope, core reaches back only inside this method).
            from repro.kernels.log_conv2d import lane_unpack_codes
            g_b, cin_lane, groups = self.layout_meta
            codes = lane_unpack_codes(self.packed, self.shape, groups,
                                      g_b, cin_lane)
            return log_dequantize(codes, self.scale, self.cfg, dtype=dtype)
        out = log_dequantize(self.packed, self.scale, self.cfg, dtype=dtype)
        return out.reshape(self.shape) if self.layout == "conv_taps" else out

    def tree_flatten(self):
        return (self.packed, self.scale), (self.cfg, self.shape, self.layout,
                                           self.layout_meta)

    @classmethod
    def tree_unflatten(cls, aux, children):
        packed, scale = children
        cfg, shape, layout, meta = (*aux, *((None,) * (4 - len(aux))))
        return cls(packed, scale, cfg, shape, layout, meta)

    def __repr__(self):
        lay = f", layout={self.layout!r}" if self.layout else ""
        return f"QuantizedTensor(shape={self.shape}, cfg={self.cfg}{lay})"


def quantize_tensor(x, cfg: LogQuantConfig = DEFAULT) -> QuantizedTensor:
    packed, scale = log_quantize(x, cfg)
    return QuantizedTensor(packed, scale, cfg, x.shape)


def dequantize_tensor(q: QuantizedTensor, dtype=jnp.bfloat16):
    return q.dequantize(dtype)


def quantization_snr_db(x, xq):
    """Signal-to-quantization-noise ratio in dB (used by the Fig-1 bench)."""
    x = np.asarray(x, np.float64)
    xq = np.asarray(xq, np.float64)
    num = np.sum(x * x)
    den = np.sum((x - xq) ** 2) + 1e-30
    return float(10.0 * np.log10(num / den + 1e-30))
