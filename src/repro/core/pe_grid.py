"""Functional model of the NeuroMAX 6×3×6 PE grid + adder nets (§4-5).

This computes *real convolution outputs* the way the RTL does, so the wiring
(2D weight broadcast, adder-net-0 row reduction, adder-net-1 column combine,
variable-length shift-register boundary psums) is testable against a dense
convolution oracle.

Grid geometry (Fig. 2/3):
    6 PE matrices × (6 rows × 3 cols) PEs × 3 threads  = 324 threads.
For a 3×3 conv, one matrix processes one input channel:
  * a 6-row × 3-col input window (row-shifted per Fig. 6) enters the matrix;
  * the 3×3 weight *matrix* is broadcast: PE column c holds weight row c,
    its 3 threads multiply one input pixel by the 3 weights of that row;
  * adder-net-0 (Fig. 4) sums same-coloured products along each PE row,
    producing 18 psums o_{r,k} = Σ_dc x[r, j+dc]·w[k, dc]  (r∈0..5, k∈0..2);
  * adder-net-1 (Fig. 9) combines psums across rows into outputs
        y[r, j] = o_{r,0} + o_{r+1,1} + o_{r+2,2};
    rows 4,5 of a band need psums from the *next* band — exactly the three
    boundary psums (o13, o17, o16) the paper passes through the VAR-len SR.

Two compute modes:
  * mode="float": thread product = w·a in fp (isolates the dataflow wiring —
    bit-exact against a direct convolution);
  * mode="log":   thread product = the fixed-point LUT+shift of
    `core.logmath` on log-quantized codes (bit-exact against what the FPGA
    would produce).

The log mode is *vectorized by default*: every thread of a whole channel
group's cycle is evaluated in one `LogPEThread.batch` numpy call (the same
LUT+shift per element), which is what makes this oracle usable to
cross-check the TPU kernels on realistic layer shapes in CI time.  Pass
``vectorized=False`` to run the original one-Python-call-per-thread
path — bit-identical, and the reference for the speedup test.

This model is the bottom tier of the repo's three-tier conv stack
(see README.md):  `kernels/log_conv2d.py` (Pallas kernel) ↔ its blockwise
jnp fallback ↔ this hardware oracle.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .logmath import LogPEThread
from .logquant import LogQuantConfig

N_MATRICES = 6
PE_ROWS = 6
PE_COLS = 3
THREADS = 3
TOTAL_THREADS = N_MATRICES * PE_ROWS * PE_COLS * THREADS  # 324


@dataclasses.dataclass
class GridStats:
    cycles: int = 0
    useful_macs: int = 0
    stored_psums: int = 0
    total_psums: int = 0
    active_thread_cycles: int = 0  # threads of matrices that held data

    @property
    def utilization(self) -> float:
        """Whole-grid utilization (idle matrices count — Fig 19 semantics)."""
        if self.cycles == 0:
            return 0.0
        return self.useful_macs / (self.cycles * TOTAL_THREADS)

    @property
    def active_utilization(self) -> float:
        """Utilization w.r.t. matrices actually loaded (§5.1/§5.2 examples)."""
        if self.active_thread_cycles == 0:
            return 0.0
        return self.useful_macs / self.active_thread_cycles

    @property
    def psum_storage_fraction(self) -> float:
        if self.total_psums == 0:
            return 0.0
        return self.stored_psums / self.total_psums


class PEMatrix:
    """One 6×3 PE matrix + its adder-net-0: emits 18 psums per cycle."""

    def __init__(self, mode: str = "float", thread: LogPEThread | None = None):
        self.mode = mode
        self.thread = thread or LogPEThread()

    def cycle_psums(self, window: np.ndarray, w: np.ndarray,
                    window_codes=None, w_codes=None, w_signs=None):
        """window: [6, 3] input pixels (cols j..j+2); w: [3, 3] weight rows.

        Returns psums o[r, k] = Σ_dc window[r, dc] · w[k, dc]   — shape [6, 3].
        In log mode the per-thread products use the fixed-point LUT+shift and
        the psums are integer accumulations (adder-net-0 is a plain adder).
        This is the per-scalar path; `cycle_psums_batch` is the vectorized
        equivalent used by the grid.
        """
        if self.mode == "float":
            # p_{r, k*3+dc} = window[r, dc] * w[k, dc]; adder-net-0 row sum
            return np.einsum("rd,kd->rk", window, w)
        # log mode: integer fixed-point accumulate
        out = np.zeros((PE_ROWS, PE_COLS), dtype=np.int64)
        for r in range(PE_ROWS):
            for k in range(PE_COLS):
                acc = 0
                for dc in range(PE_COLS):
                    acc += self.thread(
                        int(w_codes[k, dc]), int(window_codes[r, dc]),
                        int(w_signs[k, dc]),
                        a_nonzero=window[r, dc] != 0,
                        w_nonzero=w[k, dc] != 0,
                    )
                out[r, k] = acc
        return out

    def cycle_psums_batch(self, windows: np.ndarray, ws: np.ndarray,
                          window_codes=None, w_codes=None, w_signs=None):
        """`cycle_psums` for a whole channel group at once.

        windows: [nc, 6, 3]; ws: [nc, 3, 3] (one matrix per channel).
        Returns per-matrix psums o[c, r, k] — shape [nc, 6, 3]; the caller
        channel-accumulates (Fig. 13) or keeps them separate (depthwise).
        """
        if self.mode == "float":
            return np.einsum("crd,ckd->crk", windows, ws)
        prods = self.thread.batch(
            w_codes[:, None, :, :], window_codes[:, :, None, :],
            w_signs[:, None, :, :],
            a_nonzero=(windows != 0)[:, :, None, :],
            w_nonzero=(ws != 0)[:, None, :, :])      # [nc, r, k, dc]
        return prods.sum(axis=3)


class PEGrid:
    """The full 6-matrix grid with adder-net-1 + boundary shift registers."""

    def __init__(self, mode: str = "float",
                 quant_cfg: LogQuantConfig | None = None,
                 out_frac_bits: int = 12, vectorized: bool = True):
        self.mode = mode
        self.quant_cfg = quant_cfg or LogQuantConfig(per_channel=False)
        self.thread = LogPEThread(self.quant_cfg.frac_bits, out_frac_bits)
        self.matrix = PEMatrix(mode, self.thread)
        self.vectorized = vectorized

    # -- log-domain helpers (host-side state-controller work) ---------------
    def _codes(self, x):
        """Host-side log quantization of a tensor → (codes, signs, nonzero,
        scale, dequantized)."""
        import jax.numpy as jnp
        from .logquant import log_quantize, unpack, log_dequantize
        # the grid models one ⟨m,n⟩ grid per tensor (paper §3); a per-channel
        # scale array would be silently collapsed to channel 0's scale below
        assert not self.quant_cfg.per_channel, \
            "PEGrid log mode needs LogQuantConfig(per_channel=False)"
        packed, scale = log_quantize(jnp.asarray(x, jnp.float32), self.quant_cfg)
        code, sign, nz = unpack(packed, self.quant_cfg)
        deq = log_dequantize(packed, scale, self.quant_cfg)
        return (np.asarray(code), np.asarray(sign), np.asarray(nz),
                float(np.asarray(scale).reshape(-1)[0]), np.asarray(deq))

    # ------------------------------------------------------------------
    def conv2d(self, x: np.ndarray, w: np.ndarray, stride: int = 1):
        """x: [H, W, C]; w: [3, 3, C, P] (kh, kw, cin, cout). Valid padding.

        Returns (y [H_out, W_out, P], GridStats).  Channels are assigned to
        matrices 6-at-a-time (channel groups), filters iterate over passes,
        psums are channel-accumulated (Fig. 13) before adder-net-1.
        """
        assert w.shape[0] == 3 and w.shape[1] == 3, "PE grid conv is 3x3"
        if self.mode == "log" and self.vectorized:
            return self._conv2d_log_vectorized(x, w, stride)
        H, W, C = x.shape
        P = w.shape[3]
        Ho = (H - 3) // stride + 1
        Wo = (W - 3) // stride + 1
        n_bands = int(np.ceil(H / PE_ROWS))
        n_pos = W - 2  # column positions per band (stride handled at net-1)
        pos_step = stride

        log_mode = self.mode == "log"
        if log_mode:
            xc, _, _, xscale, _ = self._codes(x)
            wc, ws, _, wscale, _ = self._codes(w)
            F = float(1 << self.thread.out_frac_bits)
        stats = GridStats()
        y = np.zeros((Ho, Wo, P), dtype=np.float64)

        n_cgroups = int(np.ceil(C / N_MATRICES))
        for p in range(P):
            for cg in range(n_cgroups):
                ch0 = cg * N_MATRICES
                chans = list(range(ch0, min(ch0 + N_MATRICES, C)))
                # weight broadcast: per-matrix [3, 3] weight blocks for this
                # (filter, channel-group) pass, loaded once (2D broadcast)
                wmat = w[:, :, chans, p].transpose(2, 0, 1)        # [nc, 3, 3]
                if log_mode:
                    wcod = wc[:, :, chans, p].transpose(2, 0, 1)
                    wsgn = ws[:, :, chans, p].transpose(2, 0, 1)
                # boundary psum store: per output column j, the 3 psums
                # (o_{4,0}, o_{5,0}, o_{5,1}) of the previous band (VAR-len SR)
                sr = {}
                for b in range(n_bands):
                    r0 = b * PE_ROWS
                    rows = min(PE_ROWS, H - r0)
                    for j in range(0, n_pos, pos_step):
                        # channel-accumulated 18 psums for this (band, j)
                        o = np.zeros((PE_ROWS, PE_COLS), dtype=np.float64)
                        for ci, c in enumerate(chans):
                            win = np.zeros((PE_ROWS, PE_COLS))
                            win[:rows] = x[r0:r0 + rows, j:j + 3, c]
                            if not log_mode:
                                o += self.matrix.cycle_psums(win, wmat[ci])
                            else:
                                xcodes = np.zeros((PE_ROWS, PE_COLS),
                                                  np.int64)
                                xcodes[:rows] = xc[r0:r0 + rows, j:j + 3, c]
                                o_fx = self.matrix.cycle_psums(
                                    win, wmat[ci],
                                    window_codes=xcodes, w_codes=wcod[ci],
                                    w_signs=wsgn[ci])
                                o += o_fx / F * xscale * wscale
                        stats.cycles += 1
                        stats.total_psums += 18
                        stats.active_thread_cycles += \
                            PE_ROWS * PE_COLS * THREADS * len(chans)
                        # adder-net-1: y[r] = o[r,0] + o[r+1,1] + o[r+2,2]
                        for r in range(PE_ROWS - 2):  # rows 0..3 direct
                            ro = r0 + r
                            if ro % stride or ro // stride >= Ho or \
                               j % stride or j // stride >= Wo:
                                continue
                            val = o[r, 0] + o[r + 1, 1] + o[r + 2, 2]
                            y[ro // stride, j // stride, p] += val
                            stats.useful_macs += 9 * len(chans)
                        # boundary rows 4,5 need next band: store 3 psums
                        if r0 + PE_ROWS < H:
                            sr[(b, j)] = (o[4, 0], o[5, 0], o[5, 1])
                            stats.stored_psums += 3
                        # combine previous band's SR with this band's o[0..1]
                        if b > 0 and (b - 1, j) in sr:
                            o40, o50, o51 = sr.pop((b - 1, j))
                            for ro, val in (
                                (r0 - 2, o40 + o51 + o[0, 2]),       # row r0-2
                                (r0 - 1, o50 + o[0, 1] + o[1, 2]),   # row r0-1
                            ):
                                if ro % stride or ro // stride >= Ho or \
                                   j % stride or j // stride >= Wo:
                                    continue
                                y[ro // stride, j // stride, p] += val
                                stats.useful_macs += 9 * len(chans)
        return y.astype(np.float32), stats

    # ------------------------------------------------------------------
    def _conv2d_log_vectorized(self, x: np.ndarray, w: np.ndarray,
                               stride: int = 1):
        """Log-mode `conv2d` with every (channel-group, band) pass evaluated
        as ONE `LogPEThread.batch` call over all column positions at once.

        Numerically it is the scalar path exactly (same integer LUT+shift per
        thread, same Fig-13 channel accumulation, same adder-net-1 wiring and
        boundary shift registers, same GridStats counts) — only the Python
        loop over (j, channel, PE row, PE col, thread) is collapsed into
        numpy broadcasting, which is what makes oracle cross-checks on
        realistic layer shapes possible in CI time (≫20× faster).
        """
        H, W, C = x.shape
        P = w.shape[3]
        Ho = (H - 3) // stride + 1
        Wo = (W - 3) // stride + 1
        n_bands = int(np.ceil(H / PE_ROWS))
        n_pos = W - 2

        xc, _, _, xscale, _ = self._codes(x)
        wc, ws, _, wscale, _ = self._codes(w)
        F = float(1 << self.thread.out_frac_bits)
        stats = GridStats()
        y = np.zeros((Ho, Wo, P), dtype=np.float64)

        jj = np.arange(0, n_pos, stride)
        jo = jj // stride   # stride-aligned and jo < Wo by construction
        nj = len(jj)
        # sliding 3-wide column windows over the full row range, once
        xwin = np.lib.stride_tricks.sliding_window_view(x, 3, axis=1)
        xcwin = np.lib.stride_tricks.sliding_window_view(xc, 3, axis=1)

        n_cgroups = int(np.ceil(C / N_MATRICES))
        for p in range(P):
            for cg in range(n_cgroups):
                ch0 = cg * N_MATRICES
                chans = list(range(ch0, min(ch0 + N_MATRICES, C)))
                nc = len(chans)
                wmat = w[:, :, chans, p].transpose(2, 0, 1)      # [nc, 3, 3]
                wcod = wc[:, :, chans, p].transpose(2, 0, 1)
                wsgn = ws[:, :, chans, p].transpose(2, 0, 1)
                sr = {}
                for b in range(n_bands):
                    r0 = b * PE_ROWS
                    rows = min(PE_ROWS, H - r0)
                    # windows for every column position: [nj, nc, 6, 3]
                    win = np.zeros((nj, nc, PE_ROWS, PE_COLS))
                    xcod = np.zeros((nj, nc, PE_ROWS, PE_COLS), np.int64)
                    win[:, :, :rows] = \
                        xwin[r0:r0 + rows, jj][:, :, chans].transpose(1, 2, 0, 3)
                    xcod[:, :, :rows] = \
                        xcwin[r0:r0 + rows, jj][:, :, chans].transpose(1, 2, 0, 3)
                    prods = self.thread.batch(
                        wcod[None, :, None, :, :], xcod[:, :, :, None, :],
                        wsgn[None, :, None, :, :],
                        a_nonzero=(win != 0)[:, :, :, None, :],
                        w_nonzero=(wmat != 0)[None, :, None, :, :])
                    # adder-net-0 (dc) then Fig-13 channel accumulate (nc)
                    o = prods.sum(axis=(1, 4)) / F * xscale * wscale  # [nj,6,3]
                    stats.cycles += nj
                    stats.total_psums += 18 * nj
                    stats.active_thread_cycles += \
                        PE_ROWS * PE_COLS * THREADS * nc * nj
                    # adder-net-1 for all columns at once
                    for r in range(PE_ROWS - 2):
                        ro = r0 + r
                        if ro % stride or ro // stride >= Ho:
                            continue
                        val = o[:, r, 0] + o[:, r + 1, 1] + o[:, r + 2, 2]
                        y[ro // stride, jo, p] += val
                        stats.useful_macs += 9 * nc * nj
                    if r0 + PE_ROWS < H:
                        sr[b] = (o[:, 4, 0], o[:, 5, 0], o[:, 5, 1])
                        stats.stored_psums += 3 * nj
                    if b > 0 and b - 1 in sr:
                        o40, o50, o51 = sr.pop(b - 1)
                        for ro, val in (
                            (r0 - 2, o40 + o51 + o[:, 0, 2]),
                            (r0 - 1, o50 + o[:, 0, 1] + o[:, 1, 2]),
                        ):
                            if ro % stride or ro // stride >= Ho:
                                continue
                            y[ro // stride, jo, p] += val
                            stats.useful_macs += 9 * nc * nj
        return y.astype(np.float32), stats

    # ------------------------------------------------------------------
    def conv2d_depthwise(self, x: np.ndarray, w: np.ndarray, stride: int = 1):
        """x: [H, W, C]; w: [3, 3, C] (one 3×3 filter per channel). Valid pad.

        MobileNet's dwconv on the grid: each matrix still owns one channel,
        but there is **no** Fig-13 channel accumulation — matrix c's
        adder-net-1 output IS output channel c.  Returns (y [Ho, Wo, C],
        GridStats).  Always vectorized over all channels per (band, j).
        """
        assert w.shape[:2] == (3, 3) and w.shape[2] == x.shape[2]
        H, W, C = x.shape
        Ho = (H - 3) // stride + 1
        Wo = (W - 3) // stride + 1
        n_bands = int(np.ceil(H / PE_ROWS))
        n_pos = W - 2
        n_cgroups = int(np.ceil(C / N_MATRICES))

        log_mode = self.mode == "log"
        wmat = w.transpose(2, 0, 1)                              # [C, 3, 3]
        if log_mode:
            xc, _, _, xscale, _ = self._codes(x)
            wc, wsg, _, wscale, _ = self._codes(w)
            wcod = wc.transpose(2, 0, 1)
            wsgn = wsg.transpose(2, 0, 1)
            F = float(1 << self.thread.out_frac_bits)
        stats = GridStats()
        y = np.zeros((Ho, Wo, C), dtype=np.float64)
        sr = {}
        for b in range(n_bands):
            r0 = b * PE_ROWS
            rows = min(PE_ROWS, H - r0)
            for j in range(0, n_pos, stride):
                win = np.zeros((C, PE_ROWS, PE_COLS))
                win[:, :rows] = x[r0:r0 + rows, j:j + 3, :].transpose(2, 0, 1)
                if log_mode:
                    xcod = np.zeros((C, PE_ROWS, PE_COLS), np.int64)
                    xcod[:, :rows] = \
                        xc[r0:r0 + rows, j:j + 3, :].transpose(2, 0, 1)
                    o_fx = self.matrix.cycle_psums_batch(
                        win, wmat, window_codes=xcod, w_codes=wcod,
                        w_signs=wsgn)
                    o = o_fx / F * xscale * wscale               # [C, 6, 3]
                else:
                    o = self.matrix.cycle_psums_batch(win, wmat)
                stats.cycles += n_cgroups
                stats.total_psums += 18 * n_cgroups
                stats.active_thread_cycles += PE_ROWS * PE_COLS * THREADS * C
                jo = j // stride     # < Wo since j ranges over [0, W-2)
                for r in range(PE_ROWS - 2):
                    ro = r0 + r
                    if ro % stride or ro // stride >= Ho:
                        continue
                    y[ro // stride, jo, :] += \
                        o[:, r, 0] + o[:, r + 1, 1] + o[:, r + 2, 2]
                    stats.useful_macs += 9 * C
                if r0 + PE_ROWS < H:
                    sr[(b, j)] = (o[:, 4, 0], o[:, 5, 0], o[:, 5, 1])
                    stats.stored_psums += 3 * C
                if b > 0 and (b - 1, j) in sr:
                    o40, o50, o51 = sr.pop((b - 1, j))
                    for ro, val in (
                        (r0 - 2, o40 + o51 + o[:, 0, 2]),
                        (r0 - 1, o50 + o[:, 0, 1] + o[:, 1, 2]),
                    ):
                        if ro % stride or ro // stride >= Ho:
                            continue
                        y[ro // stride, jo, :] += val
                        stats.useful_macs += 9 * C
        return y.astype(np.float32), stats

    # ------------------------------------------------------------------
    def conv2d_1x1(self, x: np.ndarray, w: np.ndarray):
        """x: [H, W, C]; w: [C, P].  Channel-parallel mapping of §5.2:

        each matrix takes 3 channels (one per thread), 18 pixel slots per
        cycle, channel accumulation across matrices (Fig. 13)."""
        H, W, C = x.shape
        P = w.shape[1]
        stats = GridStats()
        log_mode = self.mode == "log"
        if log_mode:
            xc, _, _, xscale, _ = self._codes(x)
            wc, ws, _, wscale, _ = self._codes(w)
            wcf = wc.reshape(C, P)
            wsf = ws.reshape(C, P)
            xcf = xc.reshape(H * W, C)
            F = float(1 << self.thread.out_frac_bits)
        pix = x.reshape(H * W, C)
        y = np.zeros((H * W, P), dtype=np.float64)
        ch_per_group = N_MATRICES * THREADS  # 18 channels in flight
        n_cgroups = int(np.ceil(C / ch_per_group))
        n_ptiles = int(np.ceil(H * W / (PE_ROWS * PE_COLS)))  # 18 pixels/cycle
        for p in range(P):
            for cg in range(n_cgroups):
                c0 = cg * ch_per_group
                c1 = min(c0 + ch_per_group, C)
                for t in range(n_ptiles):
                    i0, i1 = t * 18, min((t + 1) * 18, H * W)
                    if not log_mode:
                        y[i0:i1, p] += pix[i0:i1, c0:c1] @ w[c0:c1, p]
                    elif self.vectorized:
                        # all 18×18 thread slots of the tile in one batch
                        prods = self.thread.batch(
                            wcf[None, c0:c1, p], xcf[i0:i1, c0:c1],
                            wsf[None, c0:c1, p],
                            a_nonzero=pix[i0:i1, c0:c1] != 0,
                            w_nonzero=w[None, c0:c1, p] != 0)
                        y[i0:i1, p] += prods.sum(axis=1) / F * xscale * wscale
                    else:
                        acc = np.zeros(i1 - i0, dtype=np.float64)
                        for c in range(c0, c1):
                            prods = np.array([
                                self.thread(int(wcf[c, p]), int(xcf[i, c]),
                                            int(wsf[c, p]),
                                            a_nonzero=pix[i, c] != 0,
                                            w_nonzero=w[c, p] != 0)
                                for i in range(i0, i1)], dtype=np.float64)
                            acc += prods / F * xscale * wscale
                        y[i0:i1, p] += acc
                    stats.cycles += 1
                    stats.useful_macs += (i1 - i0) * (c1 - c0)
                    stats.total_psums += 18
                    # a matrix holds 3 channels × 18 pixel slots
                    stats.active_thread_cycles += \
                        PE_ROWS * PE_COLS * THREADS * \
                        int(np.ceil((c1 - c0) / THREADS))
        return y.reshape(H, W, P).astype(np.float32), stats
