from .pipeline import DataConfig, ShardedLoader, make_loader  # noqa: F401
