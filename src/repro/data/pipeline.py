"""Deterministic, sharded input pipeline with exact resume.

Two sources:
  synthetic   counter-based PRNG tokens — each (step, host_shard) batch is a
              pure function of (seed, step), so restart at step k reproduces
              byte-identical batches with zero stored state.
  memmap      fixed-length token documents in a flat .bin (np.memmap);
              deterministic shuffled window order from (seed, epoch).

Both shard the global batch across data-parallel hosts: host h of H gets
rows [h*B/H, (h+1)*B/H).  Resume = construct loader with the same seed and
call `loader.batch(step)` — no iterator state to checkpoint beyond the step
counter that the training checkpoint already holds.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab: int
    seed: int = 0
    source: str = "synthetic"        # synthetic | memmap
    path: str | None = None          # memmap token file
    n_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0, \
            f"global_batch {self.global_batch} % n_hosts {self.n_hosts}"
        return self.global_batch // self.n_hosts


class ShardedLoader:
    """batch(step) -> {"tokens", "labels", "mask"} for this host's shard."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.source == "memmap":
            if cfg.path is None:
                raise ValueError("memmap source needs cfg.path")
            self._data = np.memmap(cfg.path, dtype=np.int32, mode="r")
            self._n_windows = len(self._data) // (cfg.seq_len + 1)
            if self._n_windows < 1:
                raise ValueError("memmap file shorter than one window")

    # -- deterministic per-(step, row) token generation -------------------
    def _synthetic_rows(self, step: int) -> np.ndarray:
        cfg = self.cfg
        row0 = step * cfg.global_batch + cfg.host_id * cfg.host_batch
        out = np.empty((cfg.host_batch, cfg.seq_len + 1), np.int32)
        for i in range(cfg.host_batch):
            # Philox counter PRNG keyed by (seed, global_row) — O(1) seek.
            rng = np.random.Generator(
                np.random.Philox(key=cfg.seed, counter=row0 + i))
            # Zipf-ish marginals so losses resemble text, not uniform noise.
            z = rng.zipf(1.3, size=cfg.seq_len + 1)
            out[i] = np.minimum(z, cfg.vocab - 1)
        return out

    def _memmap_rows(self, step: int) -> np.ndarray:
        cfg = self.cfg
        W = cfg.seq_len + 1
        epoch, within = divmod(step * cfg.global_batch, self._n_windows)
        order = np.random.Generator(
            np.random.Philox(key=cfg.seed + epoch)).permutation(
                self._n_windows)
        row0 = within + cfg.host_id * cfg.host_batch
        idx = order[(row0 + np.arange(cfg.host_batch)) % self._n_windows]
        return np.stack([self._data[j * W:(j + 1) * W] for j in idx]) \
            .astype(np.int32)

    def batch(self, step: int) -> dict:
        rows = (self._synthetic_rows(step) if self.cfg.source == "synthetic"
                else self._memmap_rows(step))
        return {"tokens": rows[:, :-1],
                "labels": rows[:, 1:],
                "mask": np.ones((self.cfg.host_batch, self.cfg.seq_len),
                                np.float32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_loader(cfg, shape: dict, *, seed=0, source="synthetic", path=None,
                n_hosts=1, host_id=0) -> ShardedLoader:
    """cfg: ModelConfig; shape: one of configs.base.SHAPES values."""
    return ShardedLoader(DataConfig(
        seq_len=shape["seq_len"], global_batch=shape["global_batch"],
        vocab=cfg.vocab, seed=seed, source=source, path=path,
        n_hosts=n_hosts, host_id=host_id))
