"""Pallas TPU kernels for the perf-critical hot spots + pure-jnp oracles.

log_matmul       decode 6-bit log codes in VMEM → MXU dot (NeuroMAX PE path)
flash_attention  blockwise online-softmax attention (causal / window / GQA)
wkv6             chunked RWKV6 WKV scan with data-dependent decay
"""
from . import ops, ref
from .ops import attention, log_matmul, wkv6
