"""Pallas TPU kernels for the perf-critical hot spots + pure-jnp oracles.

log_matmul       decode 6-bit log codes in VMEM → MXU dot (NeuroMAX PE path)
log_conv2d       NHWC conv against packed log codes: fused implicit-im2col
                 kernel (VMEM patch extraction, grouped-conv grid) plus the
                 explicit-im2col fallback onto log_matmul
autotune         per-layer block-size search + op-keyed on-disk tuning
                 table (conv2d and attention namespaces)
flash_attention  blockwise online-softmax attention, GQA-native (kv-head
                 grid dimension, causal / window, traced decode offsets)
wkv6             chunked RWKV6 WKV scan with data-dependent decay

Every op is exposed through `ops` with the unified dispatch surface —
``impl="pallas|blockwise|ref|auto"`` (convs add ``"pallas_im2col"``),
``config=`` per-op spec dataclasses (`AttentionConfig`, `ConvConfig`,
`WkvConfig`), ``interpret=`` and (for the tiled kernels)
``autotune=``; `ops.resolve_impl` documents the precedence order.
"""
from . import ops, ref
from .ops import (AttentionConfig, ConvConfig, WkvConfig, attention, conv2d,
                  log_matmul, resolve_impl, wkv6)
