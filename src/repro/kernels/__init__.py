"""Pallas TPU kernels for the perf-critical hot spots + pure-jnp oracles.

log_matmul       decode 6-bit log codes in VMEM → MXU dot (NeuroMAX PE path)
log_conv2d       NHWC conv against packed log codes (im2col onto log_matmul)
flash_attention  blockwise online-softmax attention (causal / window / GQA)
wkv6             chunked RWKV6 WKV scan with data-dependent decay

Every op is exposed through `ops` with an ``impl="pallas|blockwise|ref"``
dispatch knob; see `ops.conv2d` for the unified log-domain conv entry point.
"""
from . import ops, ref
from .ops import attention, conv2d, log_matmul, wkv6
