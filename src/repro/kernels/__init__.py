"""Pallas TPU kernels for the perf-critical hot spots + pure-jnp oracles.

log_matmul       decode 6-bit log codes in VMEM → MXU dot (NeuroMAX PE path)
log_conv2d       NHWC conv against packed log codes: fused implicit-im2col
                 kernel (VMEM patch extraction, grouped-conv grid) plus the
                 explicit-im2col fallback onto log_matmul
autotune         per-layer block-size search + on-disk tuning table for the
                 fused conv kernel
flash_attention  blockwise online-softmax attention (causal / window / GQA)
wkv6             chunked RWKV6 WKV scan with data-dependent decay

Every op is exposed through `ops` with an ``impl="pallas|blockwise|ref"``
dispatch knob (convs add ``"pallas_im2col"``); see `ops.conv2d` for the
unified log-domain conv entry point.
"""
from . import ops, ref
from .ops import attention, conv2d, log_matmul, wkv6
