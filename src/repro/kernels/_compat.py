"""Version compatibility shims for the Pallas TPU API.

`pltpu.CompilerParams` was renamed from `pltpu.TPUCompilerParams` across
JAX releases; resolve whichever this install provides so the kernels run
on both.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

TPUCompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")
