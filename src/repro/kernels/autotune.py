"""Op-keyed block-size autotuner for the Pallas kernels.

Per-layer dataflow/tiling choice dominates accelerator throughput (Shen
et al.'s resource partitioning, MPNA's per-layer dataflows); this module
brings that to every tiled kernel behind `kernels/ops.py`: enumerate
candidate block configs that fit the VMEM budget, measure steady-state
time per config on the live backend, and persist winners to an on-disk
tuning table so later processes skip the search.

One table serves every op.  Keys are namespaced per op —
``conv2d|<shape-key>`` entries hold `log_conv2d_fused_pallas`
(block_cin, block_cout, rows_per_tile, batch_per_tile) configs;
``attention|<shape-key>`` entries hold `flash_attention_pallas`
(block_q, block_k) configs.

Table format (JSON, atomic rename on write):

    {"version": SCHEMA_VERSION,
     "entries": {"<op>|<key>": {"config": {...}, "us": 12.3, "when": ...}}}

Keys carry everything that changes the launch: op, backend, quant config,
layer shape, stride/padding/groups (conv) or seq lengths/head
counts/masking (attention).  Invalidation is by `SCHEMA_VERSION` — bump
it when any kernel's grid or config space changes and every entry is
retuned on demand.  The table lives at ``$REPRO_AUTOTUNE_PATH`` (or
``~/.cache/repro/kernel_autotune.json``); `ops.conv2d(impl="pallas")`
and `ops.attention(impl="pallas")` consult it on every call and fall
back to `default_config` / `default_attention_config` heuristics on a
miss — tuning itself only runs when explicitly requested
(``autotune=True``).
"""

from __future__ import annotations

import json
import os
import time

import jax

from repro.core.logquant import LogQuantConfig
from repro.obs import metrics as _obs_metrics
from .flash_attention import flash_attention_pallas
from .log_conv2d import (fused_conv_geometry, lane_pack_geometry,
                         log_conv2d_fused_pallas, normalize_padding)

# v3: conv config space gained `lane_pack` (grouped-conv lane packing);
# v2: op-namespaced keys (conv2d|… / attention|…), one table for all ops
SCHEMA_VERSION = 3

# VMEM high-water mark a candidate launch may plan for (double-buffered)
VMEM_BUDGET_BYTES = 8 << 20

_CACHE: dict | None = None  # lazy-loaded table, invalidated via reset_cache()


def table_path() -> str:
    p = os.environ.get("REPRO_AUTOTUNE_PATH")
    if p:
        return p
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "kernel_autotune.json")


def reset_cache() -> None:
    global _CACHE
    _CACHE = None


def _load() -> dict:
    global _CACHE
    if _CACHE is None:
        _CACHE = {"version": SCHEMA_VERSION, "entries": {}}
        try:
            with open(table_path()) as f:
                t = json.load(f)
            if t.get("version") == SCHEMA_VERSION:
                _CACHE = t
        except (OSError, ValueError):
            pass
    return _CACHE


def _save(table: dict) -> None:
    path = table_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


def conv_key(B, H, W, C, K, Cout, *, stride=1, padding="SAME", groups=1,
             cfg: LogQuantConfig = LogQuantConfig(),
             backend: str | None = None) -> str:
    """Everything that changes the fused conv launch, as one namespaced key."""
    (ph0, ph1), (pw0, pw1) = normalize_padding(padding, K, stride, H, W)
    backend = backend or jax.default_backend()
    return (f"conv2d|{backend}|q{cfg.bits}.{cfg.frac_bits}"
            f"|x{B}x{H}x{W}x{C}|k{K}o{Cout}|s{stride}|g{groups}"
            f"|p{ph0}.{ph1}.{pw0}.{pw1}")


def attention_key(B, Tq, Tk, H, Hkv, D, *, causal=True, window=None,
                  backend: str | None = None) -> str:
    """Everything that changes the attention launch, as one namespaced key."""
    backend = backend or jax.default_backend()
    return (f"attention|{backend}|b{B}|q{Tq}|k{Tk}|h{H}.{Hkv}|d{D}"
            f"|c{int(bool(causal))}|w{window if window is not None else '-'}")


def lookup(key: str) -> dict | None:
    entry = _load()["entries"].get(key)
    # per-op hit/miss counters: a warm table is a latency feature, so its
    # effectiveness is a first-class metric (`autotune_lookup` in the
    # default registry, surfaced by `metrics_snapshot()`/--metrics).
    _obs_metrics.REGISTRY.counter(
        "autotune_lookup", op=key.split("|", 1)[0],
        result=("hit" if entry else "miss")).inc()
    return dict(entry["config"]) if entry else None


def record(key: str, config: dict, us: float) -> None:
    table = _load()
    table["entries"][key] = {"config": dict(config), "us": round(us, 2),
                             "when": time.strftime("%Y-%m-%dT%H:%M:%S")}
    _save(table)


# ---------------------------------------------------------------------------
# config space
# ---------------------------------------------------------------------------


def estimate_vmem_bytes(B, H, W, C, K, Cout, *, stride=1, padding="SAME",
                        groups=1, **config) -> int:
    """Planned VMEM per grid step: activation slab + weight block + psum
    accumulator + out block, ×2 for double buffering of the streamed refs."""
    g = fused_conv_geometry(B, H, W, C, K, Cout, stride=stride,
                            padding=padding, groups=groups, **config)
    slab = g["bt"] * g["rows_in"] * g["Wp"] * g["bcin"] * 4
    wblk = g["bcin"] * g["bcout"]
    acc = g["bt"] * g["rt"] * g["Wo"] * g["ow"] * 4
    # lane packing expands the decoded weight block to [Lc, bcout*g_b] f32
    # in VMEM before the dot (compact codes stay int8 in the stream)
    wexp = g["bcin"] * g["ow"] * 4 if g["g_b"] > 1 else 0
    return 2 * (slab + wblk) + 2 * acc + wexp


def default_config(B, H, W, C, K, Cout, *, stride=1, padding="SAME",
                   groups=1) -> dict:
    """Heuristic used on a tuning-table miss: MXU-sized channel blocks, one
    row tile (zero halo duplication), batch tile as wide as VMEM allows,
    lane packing on auto (engages whenever g_b ≥ 2 groups fit a lane block)."""
    return dict(block_cin=128, block_cout=128, rows_per_tile=None,
                batch_per_tile=None, lane_pack=None)


def candidate_configs(B, H, W, C, K, Cout, *, stride=1, padding="SAME",
                      groups=1, budget: int = VMEM_BUDGET_BYTES,
                      max_candidates: int = 12) -> list[dict]:
    """Candidate (block_cin, block_cout, rows_per_tile, batch_per_tile,
    lane_pack) tuples that fit the VMEM budget, deduped after geometry
    clamping.  For grouped shapes where lane packing can engage, each
    tiling is tried both packed (auto g_b) and unpacked (lane_pack=1)."""
    g0 = fused_conv_geometry(B, H, W, C, K, Cout, stride=stride,
                             padding=padding, groups=groups)
    Ho, cin_g, cout_g = g0["Ho"], g0["cin_g"], g0["cout_g"]
    rts = sorted({Ho, max(1, Ho // 2), min(Ho, 8), min(Ho, 4)})
    bcis = sorted({min(cin_g, 32), min(cin_g, 128), min(cin_g, 256)})
    bcos = sorted({min(cout_g, 32), min(cout_g, 128), min(cout_g, 256)})
    bts = [1, None]  # single batch element vs widest-fit batch tile
    packable = lane_pack_geometry(groups, cin_g)["g_b"] > 1
    lps = [None, 1] if packable else [None]  # auto-packed vs forced-off
    seen, out = set(), []
    for rt in rts:
        for bci in bcis:
            for bco in bcos:
                for bt in bts:
                    for lp in lps:
                        cfg = dict(block_cin=bci, block_cout=bco,
                                   rows_per_tile=rt, batch_per_tile=bt,
                                   lane_pack=lp)
                        g = fused_conv_geometry(B, H, W, C, K, Cout,
                                                stride=stride,
                                                padding=padding,
                                                groups=groups, **cfg)
                        sig = (g["bcin"], g["bcout"], g["rt"], g["bt"],
                               g["g_b"])
                        if sig in seen:
                            continue
                        if estimate_vmem_bytes(B, H, W, C, K, Cout,
                                               stride=stride,
                                               padding=padding,
                                               groups=groups,
                                               **cfg) > budget:
                            continue
                        seen.add(sig)
                        out.append(cfg)
    # prefer fewer, larger tiles first so the search front-loads likely wins
    out.sort(key=lambda c: (-(c["rows_per_tile"] or Ho),
                            -c["block_cout"], -c["block_cin"]))
    return out[:max_candidates]


# ---------------------------------------------------------------------------
# attention config space
# ---------------------------------------------------------------------------


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def estimate_attention_vmem_bytes(B, Tq, Tk, H, Hkv, D, *, block_q=128,
                                  block_k=128, itemsize=4) -> int:
    """Planned VMEM per grid step of `flash_attention_pallas`: q/k/v/out
    tiles (×2 double-buffered streams), the (m, l, acc) scratch carry, and
    the live [bq, bk] score/prob intermediates."""
    tiles = (block_q * D + 2 * block_k * D + block_q * D) * itemsize
    scratch = (block_q * D + 2 * block_q) * 4
    s_live = 2 * block_q * block_k * 4
    return 2 * tiles + scratch + s_live


def default_attention_config(B, Tq, Tk, H, Hkv, D) -> dict:
    """Heuristic on a tuning-table miss: MXU-friendly tiles clamped to the
    folded q-row count (rep · Tq — decode packs a whole kv group into one
    block) and the kv length."""
    rows = (H // Hkv) * Tq
    return dict(block_q=min(128, _round_up(rows, 8)),
                block_k=min(128, _round_up(Tk, 8)))


def attention_candidate_configs(B, Tq, Tk, H, Hkv, D, *,
                                budget: int = VMEM_BUDGET_BYTES,
                                max_candidates: int = 12) -> list[dict]:
    """Candidate (block_q, block_k) pairs that fit the VMEM budget,
    deduped after clamping to the folded-row/kv extents."""
    rows = (H // Hkv) * Tq
    bqs = sorted({min(_round_up(rows, 8), bq) for bq in (32, 64, 128, 256)})
    bks = sorted({min(_round_up(Tk, 8), bk) for bk in (128, 256, 512, 1024)})
    seen, out = set(), []
    for bq in bqs:
        for bk in bks:
            if (bq, bk) in seen:
                continue
            if estimate_attention_vmem_bytes(B, Tq, Tk, H, Hkv, D,
                                             block_q=bq, block_k=bk) > budget:
                continue
            seen.add((bq, bk))
            out.append(dict(block_q=bq, block_k=bk))
    # larger tiles first: fewer grid steps usually wins on hardware
    out.sort(key=lambda c: (-c["block_q"] * c["block_k"], -c["block_k"]))
    return out[:max_candidates]


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------


def _time_config(x, packed, scale, qcfg, kw, config, reps: int) -> float:
    fn = lambda: log_conv2d_fused_pallas(x, packed, scale, qcfg, **kw,
                                         **config)
    jax.block_until_ready(fn())        # compile
    jax.block_until_ready(fn())        # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def autotune_conv2d(x, packed, scale, qcfg: LogQuantConfig, *, stride=1,
                    padding="SAME", groups=1, interpret=False, reps: int = 3,
                    max_candidates: int = 12) -> dict:
    """Measure candidates for this layer shape, persist and return the best.

    Steady-state timing (compile excluded); the winner lands in the on-disk
    table under `conv_key(...)` so every later process starts warm.
    """
    B, H, W, C = x.shape
    K, Cout = packed.shape[0], packed.shape[-1]
    shape_kw = dict(stride=stride, padding=padding, groups=groups)
    key = conv_key(B, H, W, C, K, Cout, cfg=qcfg, **shape_kw,
                   backend=("interpret" if interpret
                            else jax.default_backend()))
    kw = dict(interpret=interpret, **shape_kw)
    best, best_us = None, float("inf")
    for config in (candidate_configs(B, H, W, C, K, Cout, **shape_kw,
                                     max_candidates=max_candidates)
                   or [default_config(B, H, W, C, K, Cout, **shape_kw)]):
        us = _time_config(x, packed, scale, qcfg, kw, config, reps)
        if us < best_us:
            best, best_us = config, us
    record(key, best, best_us)
    return dict(best)


def autotune_attention(q, k, v, *, causal=True, window=None, scale=None,
                       interpret=False, reps: int = 3,
                       max_candidates: int = 12) -> dict:
    """Measure (block_q, block_k) candidates for this attention shape,
    persist and return the best.

    Steady-state timing (compile excluded); the winner lands in the
    on-disk table under `attention_key(...)` so every later process
    starts warm.  Offsets don't enter the key — they are scalar-prefetch
    operands, not launch geometry."""
    B, Tq, H, D = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    key = attention_key(B, Tq, Tk, H, Hkv, D, causal=causal, window=window,
                        backend=("interpret" if interpret
                                 else jax.default_backend()))
    shape = (B, Tq, Tk, H, Hkv, D)
    best, best_us = None, float("inf")
    for config in (attention_candidate_configs(*shape,
                                               max_candidates=max_candidates)
                   or [default_attention_config(*shape)]):
        fn = lambda: flash_attention_pallas(q, k, v, causal=causal,
                                            window=window, scale=scale,
                                            interpret=interpret, **config)
        jax.block_until_ready(fn())        # compile
        jax.block_until_ready(fn())        # warm
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn()
        jax.block_until_ready(out)
        us = (time.perf_counter() - t0) / reps * 1e6
        if us < best_us:
            best, best_us = config, us
    record(key, best, best_us)
    return dict(best)
