"""Block-size autotuner for the fused log-conv kernel.

Per-layer dataflow/tiling choice dominates conv accelerator throughput
(Shen et al.'s resource partitioning, MPNA's per-layer dataflows); this
module brings that to `log_conv2d_fused_pallas`: enumerate candidate
(block_cin, block_cout, rows_per_tile, batch_per_tile) configs that fit
the VMEM budget, measure steady-state time per config on the live backend,
and persist winners to an on-disk tuning table so later processes skip the
search.

Table format (JSON, atomic rename on write):

    {"version": SCHEMA_VERSION,
     "entries": {"<key>": {"config": {...}, "us": 12.3, "when": ...}}}

Keys carry everything that changes the launch: backend, quant config,
layer shape, stride, resolved padding, groups.  Invalidation is by
`SCHEMA_VERSION` — bump it when the kernel's grid or config space changes
and every entry is retuned on demand.  The table lives at
``$REPRO_AUTOTUNE_PATH`` (or ``~/.cache/repro/conv_autotune.json``);
`ops.conv2d(impl="pallas")` consults it on every call and falls back to
`default_config` heuristics on a miss — tuning itself only runs when
explicitly requested (``autotune=True``).
"""

from __future__ import annotations

import json
import os
import time

import jax

from repro.core.logquant import LogQuantConfig
from .log_conv2d import (fused_conv_geometry, log_conv2d_fused_pallas,
                         normalize_padding)

SCHEMA_VERSION = 1

# VMEM high-water mark a candidate launch may plan for (double-buffered)
VMEM_BUDGET_BYTES = 8 << 20

_CACHE: dict | None = None  # lazy-loaded table, invalidated via reset_cache()


def table_path() -> str:
    p = os.environ.get("REPRO_AUTOTUNE_PATH")
    if p:
        return p
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "conv_autotune.json")


def reset_cache() -> None:
    global _CACHE
    _CACHE = None


def _load() -> dict:
    global _CACHE
    if _CACHE is None:
        _CACHE = {"version": SCHEMA_VERSION, "entries": {}}
        try:
            with open(table_path()) as f:
                t = json.load(f)
            if t.get("version") == SCHEMA_VERSION:
                _CACHE = t
        except (OSError, ValueError):
            pass
    return _CACHE


def _save(table: dict) -> None:
    path = table_path()
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


def conv_key(B, H, W, C, K, Cout, *, stride=1, padding="SAME", groups=1,
             cfg: LogQuantConfig = LogQuantConfig(),
             backend: str | None = None) -> str:
    """Everything that changes the fused launch, flattened to one string."""
    (ph0, ph1), (pw0, pw1) = normalize_padding(padding, K, stride, H, W)
    backend = backend or jax.default_backend()
    return (f"{backend}|q{cfg.bits}.{cfg.frac_bits}"
            f"|x{B}x{H}x{W}x{C}|k{K}o{Cout}|s{stride}|g{groups}"
            f"|p{ph0}.{ph1}.{pw0}.{pw1}")


def lookup(key: str) -> dict | None:
    entry = _load()["entries"].get(key)
    return dict(entry["config"]) if entry else None


def record(key: str, config: dict, us: float) -> None:
    table = _load()
    table["entries"][key] = {"config": dict(config), "us": round(us, 2),
                             "when": time.strftime("%Y-%m-%dT%H:%M:%S")}
    _save(table)


# ---------------------------------------------------------------------------
# config space
# ---------------------------------------------------------------------------


def estimate_vmem_bytes(B, H, W, C, K, Cout, *, stride=1, padding="SAME",
                        groups=1, **config) -> int:
    """Planned VMEM per grid step: activation slab + weight block + psum
    accumulator + out block, ×2 for double buffering of the streamed refs."""
    g = fused_conv_geometry(B, H, W, C, K, Cout, stride=stride,
                            padding=padding, groups=groups, **config)
    slab = g["bt"] * g["rows_in"] * g["Wp"] * g["bcin"] * 4
    wblk = g["bcin"] * g["bcout"]
    acc = g["bt"] * g["rt"] * g["Wo"] * g["bcout"] * 4
    return 2 * (slab + wblk) + 2 * acc


def default_config(B, H, W, C, K, Cout, *, stride=1, padding="SAME",
                   groups=1) -> dict:
    """Heuristic used on a tuning-table miss: MXU-sized channel blocks, one
    row tile (zero halo duplication), batch tile as wide as VMEM allows."""
    return dict(block_cin=128, block_cout=128, rows_per_tile=None,
                batch_per_tile=None)


def candidate_configs(B, H, W, C, K, Cout, *, stride=1, padding="SAME",
                      groups=1, budget: int = VMEM_BUDGET_BYTES,
                      max_candidates: int = 12) -> list[dict]:
    """Candidate (block_cin, block_cout, rows_per_tile, batch_per_tile)
    tuples that fit the VMEM budget, deduped after geometry clamping."""
    g0 = fused_conv_geometry(B, H, W, C, K, Cout, stride=stride,
                             padding=padding, groups=groups)
    Ho, cin_g, cout_g = g0["Ho"], g0["cin_g"], g0["cout_g"]
    rts = sorted({Ho, max(1, Ho // 2), min(Ho, 8), min(Ho, 4)})
    bcis = sorted({min(cin_g, 32), min(cin_g, 128), min(cin_g, 256)})
    bcos = sorted({min(cout_g, 32), min(cout_g, 128), min(cout_g, 256)})
    bts = [1, None]  # single batch element vs widest-fit batch tile
    seen, out = set(), []
    for rt in rts:
        for bci in bcis:
            for bco in bcos:
                for bt in bts:
                    cfg = dict(block_cin=bci, block_cout=bco,
                               rows_per_tile=rt, batch_per_tile=bt)
                    g = fused_conv_geometry(B, H, W, C, K, Cout,
                                            stride=stride, padding=padding,
                                            groups=groups, **cfg)
                    sig = (g["bcin"], g["bcout"], g["rt"], g["bt"])
                    if sig in seen:
                        continue
                    if estimate_vmem_bytes(B, H, W, C, K, Cout,
                                           stride=stride, padding=padding,
                                           groups=groups, **cfg) > budget:
                        continue
                    seen.add(sig)
                    out.append(cfg)
    # prefer fewer, larger tiles first so the search front-loads likely wins
    out.sort(key=lambda c: (-(c["rows_per_tile"] or Ho),
                            -c["block_cout"], -c["block_cin"]))
    return out[:max_candidates]


# ---------------------------------------------------------------------------
# measurement
# ---------------------------------------------------------------------------


def _time_config(x, packed, scale, qcfg, kw, config, reps: int) -> float:
    fn = lambda: log_conv2d_fused_pallas(x, packed, scale, qcfg, **kw,
                                         **config)
    jax.block_until_ready(fn())        # compile
    jax.block_until_ready(fn())        # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def autotune_conv2d(x, packed, scale, qcfg: LogQuantConfig, *, stride=1,
                    padding="SAME", groups=1, interpret=False, reps: int = 3,
                    max_candidates: int = 12) -> dict:
    """Measure candidates for this layer shape, persist and return the best.

    Steady-state timing (compile excluded); the winner lands in the on-disk
    table under `conv_key(...)` so every later process starts warm.
    """
    B, H, W, C = x.shape
    K, Cout = packed.shape[0], packed.shape[-1]
    shape_kw = dict(stride=stride, padding=padding, groups=groups)
    key = conv_key(B, H, W, C, K, Cout, cfg=qcfg, **shape_kw,
                   backend=("interpret" if interpret
                            else jax.default_backend()))
    kw = dict(interpret=interpret, **shape_kw)
    best, best_us = None, float("inf")
    for config in (candidate_configs(B, H, W, C, K, Cout, **shape_kw,
                                     max_candidates=max_candidates)
                   or [default_config(B, H, W, C, K, Cout, **shape_kw)]):
        us = _time_config(x, packed, scale, qcfg, kw, config, reps)
        if us < best_us:
            best, best_us = config, us
    record(key, best, best_us)
    return dict(best)
