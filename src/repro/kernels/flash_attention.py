"""Pallas TPU kernel: blockwise online-softmax attention, GQA-native.

Perf-critical hot spot for the prefill_32k / long-context cells: a full
[Tq, Tk] score matrix at 32k² is ~4 GB per head in fp32 — blockwise online
softmax keeps the working set at (bq × bk) in VMEM.  Supports causal
masking and sliding windows (gemma3 local layers, RecurrentGemma local
attention).

GQA/MQA is native: the grid carries an explicit kv-head dimension and the
`rep = H // Hkv` query heads of each group are folded into the q-row axis,
so one K/V tile is DMA'd into VMEM per (batch, kv head, q block, kv block)
step and broadcast across all of its query heads — the paper's 2D
weight-broadcast dataflow, applied to K/V operands.  K/V HBM traffic
scales with Hkv, not H (no `jnp.repeat` expansion anywhere).

Decode offsets (`q_offset`, `k_offset`) are scalar-prefetch operands, so
they may be traced values: single-token decode at a dynamic cache index
runs on this kernel instead of falling back to the jnp path.

Grid: (batch, kv_heads, q_blocks, kv_blocks), kv innermost ("arbitrary"
semantics) with running (m, l, acc) scratch carried across kv steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import TPUCompilerParams

NEG_INF = -1e30


def _attn_kernel(off_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                 *, scale, causal, window, block_q, block_k, q_len, kv_len):
    kv = pl.program_id(3)

    @pl.when(kv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale       # [bq, d]
    k = k_ref[0, 0].astype(jnp.float32)               # [bk, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]

    # q rows are the folded (rep · Tq) axis: row r belongs to query head
    # r // Tq of the group at in-head position r % Tq — all rep heads of a
    # kv group share positions, so only r % Tq feeds the mask.
    row = (pl.program_id(2) * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0))
    qpos = jax.lax.rem(row, q_len) + off_ref[0]
    kidx = (kv * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1))
    kpos = kidx + off_ref[1]
    # padded kv columns never contribute; ring slots at absolute pos < 0
    # (never written) are masked by k_offset semantics
    mask = (kidx < kv_len) & (kpos >= 0)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                               # [bq, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (exp(NEG_INF - NEG_INF) would be 1)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv == pl.num_programs(3) - 1)
    def _flush():
        l = jnp.where(l_ref[...] > 0, l_ref[...], 1.0)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret", "scale"))
def flash_attention_pallas(q, k, v, *, causal=True, window=None, scale=None,
                           q_offset=0, k_offset=0, block_q=128, block_k=128,
                           interpret=False):
    """q: [B, Tq, H, D]; k, v: [B, Tk, Hkv, D] with H a multiple of Hkv.

    `q_offset` is the absolute position of q[0] (decode: Tk - Tq);
    `k_offset` the absolute position of k[0] (ring caches) — both may be
    traced scalars (scalar-prefetch operands, not trace-time constants).
    Tq/Tk are padded to block multiples; padded kv columns are masked by
    index and padded q rows are sliced off."""
    B, Tq, H, D = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)

    # fold each kv group's `rep` query heads into the row axis, THEN pad:
    # a q block packs rows of several heads (decode: all rep heads of the
    # group in one block) so the K/V tile in VMEM serves every one of them.
    qf = q.reshape(B, Tq, Hkv, rep, D).transpose(0, 2, 3, 1, 4) \
          .reshape(B, Hkv, rep * Tq, D)
    rows = rep * Tq
    pq, pk = (-rows) % block_q, (-Tk) % block_k
    qp = jnp.pad(qf, ((0, 0), (0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, pk), (0, 0)))
    offs = jnp.stack([jnp.asarray(q_offset, jnp.int32),
                      jnp.asarray(k_offset, jnp.int32)])

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, q_len=Tq, kv_len=Tk)

    grid = (B, Hkv, (rows + pq) // block_q, (Tk + pk) // block_k)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, block_q, D),
                             lambda b, h, i, j, off: (b, h, i, 0)),
                pl.BlockSpec((1, 1, block_k, D),
                             lambda b, h, i, j, off: (b, h, j, 0)),
                pl.BlockSpec((1, 1, block_k, D),
                             lambda b, h, i, j, off: (b, h, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, block_q, D),
                                   lambda b, h, i, j, off: (b, h, i, 0)),
            scratch_shapes=[
                pltpu.VMEM((block_q, 1), jnp.float32),
                pltpu.VMEM((block_q, 1), jnp.float32),
                pltpu.VMEM((block_q, D), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, rows + pq, D), q.dtype),
        interpret=interpret,
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(offs, qp, kp, vp)
    return out[:, :, :rows].reshape(B, Hkv, rep, Tq, D) \
              .transpose(0, 3, 1, 2, 4).reshape(B, Tq, H, D)


# ---------------------------------------------------------------------------
# analytic HBM traffic
# ---------------------------------------------------------------------------


def attention_traffic_bytes(impl: str, B: int, Tq: int, Tk: int, H: int,
                            Hkv: int, D: int, *, block_q: int = 128,
                            block_k: int = 128, itemsize: int = 4) -> dict:
    """Bytes moved HBM↔VMEM for one attention call, per implementation.

    First-order model (same conventions as `log_conv2d.conv_traffic_bytes`):
    counts every block fetch the grid performs — K/V tiles are re-read once
    per q block, q and out move once — plus any HBM materialisation the
    path needs.  ``"repeat"`` models the legacy dispatch that expanded K/V
    to H heads with `jnp.repeat` before a per-(batch·head) kernel: the
    expanded arrays are written to HBM and then streamed per q block, so
    its K/V term scales with H while the native ``"pallas"`` path scales
    with Hkv.  Returns ``{"q", "kv", "out", "total"}``.
    """
    rep = H // Hkv
    q_b = B * Tq * H * D * itemsize
    out_b = q_b
    kv_arr = 2 * B * Tk * Hkv * D * itemsize         # K and V as stored
    if impl == "pallas":                              # native GQA kernel
        n_qb = -(-rep * Tq // block_q)                # folded-row q blocks
        kv = kv_arr * n_qb
    elif impl == "repeat":                            # legacy expand path
        n_qb = -(-Tq // block_q)                      # per-head q blocks
        kv = kv_arr * rep + kv_arr * rep * n_qb       # materialise + stream
    elif impl == "blockwise":
        # lax.scan over kv chunks with all heads resident: K/V once.
        kv = kv_arr
    elif impl == "ref":
        # full score matrix hits HBM (write + read), K/V rep-expanded.
        kv = kv_arr * rep + 2 * B * H * Tq * Tk * itemsize
    else:
        raise ValueError(f"unknown impl {impl!r}")
    return {"q": int(q_b), "kv": int(kv), "out": int(out_b),
            "total": int(q_b + kv + out_b)}
