"""Pallas TPU kernel: blockwise online-softmax attention (causal / window).

Perf-critical hot spot for the prefill_32k / long-context cells: a full
[Tq, Tk] score matrix at 32k² is ~4 GB per head in fp32 — blockwise online
softmax keeps the working set at (bq × bk) in VMEM.  Supports GQA (the
wrapper maps kv heads), causal masking, and sliding windows (gemma3 local
layers, RecurrentGemma local attention).

Grid: (batch·heads, q_blocks, kv_blocks), kv innermost ("arbitrary"
semantics) with running (m, l, acc) scratch carried across kv steps.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import TPUCompilerParams

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale, causal, window, block_q, block_k, q_offset, kv_len):
    kv = pl.program_id(2)

    @pl.when(kv == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # [bq, d]
    k = k_ref[0].astype(jnp.float32)                  # [bk, d]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [bq, bk]

    qpos = (pl.program_id(1) * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + q_offset)
    kpos = (kv * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1))
    mask = kpos < kv_len  # padded kv columns never contribute
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                               # [bq, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # guard fully-masked rows (exp(NEG_INF - NEG_INF) would be 1)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot_general(
        p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv == pl.num_programs(2) - 1)
    def _flush():
        l = jnp.where(l_ref[...] > 0, l_ref[...], 1.0)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret", "scale",
                                             "q_offset"))
def flash_attention_pallas(q, k, v, *, causal=True, window=None, scale=None,
                           q_offset=0, block_q=128, block_k=128,
                           interpret=False):
    """q: [BH, Tq, D]; k, v: [BH, Tk, D] (GQA mapping done by the wrapper).

    Tq/Tk are padded to block multiples; padded kv columns are masked by
    position (kpos > real positions are never unmasked because causal/window
    masks use real positions and padded q rows are sliced off)."""
    BH, Tq, D = q.shape
    Tk = k.shape[1]
    scale = scale if scale is not None else 1.0 / (D ** 0.5)

    pq, pk = (-Tq) % block_q, (-Tk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
    Tqp, Tkp = Tq + pq, Tk + pk

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, q_offset=q_offset, kv_len=Tk)

    out = pl.pallas_call(
        kernel,
        grid=(BH, Tqp // block_q, Tkp // block_k),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tqp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(qp, kp, vp)
    return out[:, :Tq]
