"""NHWC conv2d against packed 6-bit(+sign) log-quantized weights.

This is the conv realisation of the NeuroMAX log-PE + 2D weight-broadcast
dataflow on TPU, and the middle of the repo's three-tier conv stack:

    kernels/log_conv2d.py  (this file, Pallas + blockwise + ref)
        ↕  numerics cross-checked in tests/test_conv2d.py
    core/pe_grid.py        (cycle-accurate 6×3×6 PE-grid hardware oracle)

Four implementations share one contract (see `kernels/ops.conv2d` for the
dispatch layer):

  * ``log_conv2d_fused_pallas`` — direct NHWC conv: patch extraction
    happens *in VMEM* (implicit im2col).  The grid walks (batch·row tiles,
    groups, output-channel tiles, reduction over Cin blocks × K² taps);
    an activation slab is loaded once per tile and re-sliced for every tap
    (line-buffer-style reuse of the paper's §5 weight broadcast — no K²×
    patch blow-up in HBM), weight codes stay packed int8 in HBM and decode
    next to the MXU (eq. 8's LUT+shift as `exp2` of a half-integer), and
    psums stay in the VMEM accumulator until flush.  Grouped/depthwise
    convs are a grid dimension over groups — each step contracts only its
    group's `cin_g` slice, so no block-diagonal `groups`× byte/FLOP waste.
    Block sizes (`block_cin/block_cout/rows_per_tile/batch_per_tile`) are
    tunable; `kernels/autotune.py` measures and persists winners.
  * ``log_conv2d_pallas`` — the explicit-im2col fallback: patches are
    materialised in HBM and tiled onto the `log_matmul_pallas` MXU kernel
    (grouped convs as a block-diagonal code matrix whose out-of-group
    entries hold the dedicated zero code).  K²× activation traffic, kept
    as `impl="pallas_im2col"` for cross-checking and as the known-good
    lowering.
  * ``log_conv2d_blockwise`` — decode-then-`lax.conv` in jnp.  XLA fuses the
    int8→float decode into the convolution's weight operand, so the weight
    bytes that move stay int8 (same memory behaviour as the kernel); this
    is what model lowering uses on every backend without Pallas.
  * ``log_conv2d_ref`` — full-materialisation oracle: explicit im2col
    patches against `ref.ref_log_matmul` at highest precision.  Independent
    of `lax.conv`, so it cross-validates the patch extraction itself.

All four take the same packed layout: ``packed [K, K, Cin//groups, Cout]``
int8 codes with a per-output-channel (or scalar) fp scale, `stride`,
`padding` ("SAME"/"VALID"/int/explicit pairs) and `groups`.
`conv_traffic_bytes` is the shared analytic HBM-traffic model the conv
benchmark reports per impl.

Grouped/depthwise convs additionally support a **lane-packed** layout on
the fused kernel (see `lane_pack_geometry`): on real TPUs the MXU/VPU
lane dimension is 128 wide, so a contraction over one group's `cin_g`
channels occupies a full 128-lane block no matter how narrow the group —
at depthwise `cin_g = 1` that is 1/128 lane density.  Lane packing
arranges ``G_b = floor(128 / cin_lane)`` groups side by side in one lane
block (``cin_lane`` = `cin_g` padded to a power of two) so one MXU pass
contracts `G_b` groups at once; the compact codes are **unpacked next to
the MXU** by an in-kernel masked broadcast (lane `l` serves group
``l // cin_lane``; out-of-group taps multiply by an exact 0), so HBM
weight traffic stays compact — no block-diagonal expansion ever leaves
VMEM.  `serving/quantize.quantize_cnn_params(conv_layout="lane_packed")`
bakes the layout at load time; `ops.ConvConfig(lane_pack=...)` selects it
per call.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.logquant import LogQuantConfig, log_dequantize
from ._compat import TPUCompilerParams
from .log_matmul import _decode_block, log_matmul_pallas
from .ref import ref_log_matmul

DEFAULT_CFG = LogQuantConfig()


# ---------------------------------------------------------------------------
# shared plumbing
# ---------------------------------------------------------------------------


def _pad_pair(size: int, k: int, stride: int) -> tuple[int, int]:
    """XLA-style SAME padding for one spatial dim."""
    out = -(-size // stride)
    total = max((out - 1) * stride + k - size, 0)
    return total // 2, total - total // 2


def normalize_padding(padding, K: int, stride: int, H: int, W: int):
    """→ ((lo_h, hi_h), (lo_w, hi_w)), accepting SAME/VALID/int/pairs."""
    if isinstance(padding, str):
        p = padding.upper()
        if p == "VALID":
            return (0, 0), (0, 0)
        if p == "SAME":
            return _pad_pair(H, K, stride), _pad_pair(W, K, stride)
        raise ValueError(f"unknown padding {padding!r}")
    if isinstance(padding, int):
        return (padding, padding), (padding, padding)
    (ph, pw) = padding
    if isinstance(ph, int):
        return (ph, ph), (pw, pw)
    return tuple(ph), tuple(pw)


def _out_size(size: int, k: int, stride: int, pads: tuple[int, int]) -> int:
    return (size + pads[0] + pads[1] - k) // stride + 1


def _im2col(x, K: int, stride: int, pads):
    """x: [B, H, W, C] → patches [B, Ho, Wo, K*K*C], tap-major (kh, kw, c).

    The tap ordering matches ``w.reshape(K*K*Cin, Cout)`` of an HWIO kernel,
    so a plain matmul against the reshaped weight is the convolution.
    """
    B, H, W, C = x.shape
    (ph0, ph1), (pw0, pw1) = pads
    xp = jnp.pad(x, ((0, 0), (ph0, ph1), (pw0, pw1), (0, 0)))
    Ho = _out_size(H, K, stride, (ph0, ph1))
    Wo = _out_size(W, K, stride, (pw0, pw1))
    taps = []
    for kh in range(K):
        for kw in range(K):
            taps.append(jax.lax.slice(
                xp, (0, kh, kw, 0),
                (B, kh + (Ho - 1) * stride + 1, kw + (Wo - 1) * stride + 1, C),
                (1, stride, stride, 1)))
    patches = jnp.stack(taps, axis=3)            # [B, Ho, Wo, K*K, C]
    return patches.reshape(B, Ho, Wo, K * K * C), Ho, Wo


def _block_diag_codes(packed, groups: int):
    """packed [K, K, cin_g, Cout] → [K*K*(groups·cin_g), Cout] block-diagonal
    int8 codes: row (tap, g, i) holds the code for output channels of group
    g only; everywhere else the zero code (int8 0), which decodes to 0.0."""
    K1, K2, cin_g, Cout = packed.shape
    cout_g = Cout // groups
    taps = K1 * K2
    w = packed.reshape(taps, cin_g, Cout)
    if groups == 1:
        return w.reshape(taps * cin_g, Cout)
    group_of_out = jnp.arange(Cout) // cout_g                 # [Cout]
    in_group = group_of_out[None, :] == jnp.arange(groups)[:, None]
    # [taps, g, i, o] — keep codes only where o belongs to group g
    wbd = w[:, None, :, :] * in_group[None, :, None, :].astype(packed.dtype)
    return wbd.reshape(taps * groups * cin_g, Cout)


def _check_shapes(x, packed, groups):
    B, H, W, C = x.shape
    K1, K2, cin_g, Cout = packed.shape
    assert K1 == K2, f"square kernels only, got {K1}x{K2}"
    assert C == cin_g * groups, (x.shape, packed.shape, groups)
    assert Cout % groups == 0, (Cout, groups)
    return B, H, W, C, K1, Cout


# ---------------------------------------------------------------------------
# the three implementations
# ---------------------------------------------------------------------------


def log_conv2d_pallas(x, packed, scale, cfg: LogQuantConfig = DEFAULT_CFG,
                      *, stride: int = 1, padding="SAME", groups: int = 1,
                      interpret: bool = False, out_dtype=None):
    """Packed-weight conv on the `log_matmul_pallas` MXU path via im2col."""
    B, H, W, C, K, Cout = _check_shapes(x, packed, groups)
    pads = normalize_padding(padding, K, stride, H, W)
    patches, Ho, Wo = _im2col(x, K, stride, pads)
    codes = _block_diag_codes(packed, groups)
    scale = jnp.broadcast_to(jnp.asarray(scale, jnp.float32).reshape(1, -1),
                             (1, Cout))
    out = log_matmul_pallas(patches.reshape(B * Ho * Wo, -1), codes, scale,
                            cfg, interpret=interpret,
                            out_dtype=out_dtype or x.dtype)
    return out.reshape(B, Ho, Wo, Cout)


def log_conv2d_blockwise(x, packed, scale, cfg: LogQuantConfig = DEFAULT_CFG,
                         *, stride: int = 1, padding="SAME", groups: int = 1,
                         out_dtype=None):
    """Decode-then-conv fallback; XLA keeps the moved weight bytes int8."""
    B, H, W, C, K, Cout = _check_shapes(x, packed, groups)
    pads = normalize_padding(padding, K, stride, H, W)
    scale = jnp.asarray(scale, jnp.float32).reshape(1, -1)
    w = log_dequantize(packed, scale.reshape(1, 1, 1, -1), cfg,
                       dtype=jnp.float32)
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w, window_strides=(stride, stride),
        padding=pads, dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    return y.astype(out_dtype or x.dtype)


# ---------------------------------------------------------------------------
# lane-packed grouped-conv layout
# ---------------------------------------------------------------------------

LANES = 128  # physical MXU/VPU lane width the packed layout targets


def _ceil_to(n: int, b: int) -> int:
    return -(-n // b) * b


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def lane_pack_geometry(groups: int, cin_g: int, lane_pack: int | None = None,
                       lanes: int = LANES) -> dict:
    """Resolve how many groups share one lane block for a grouped conv.

    ``lane_pack``: ``None`` → auto (pack whenever ≥2 groups fit a lane
    block), ``0``/``1`` → disabled (the padded per-group path), ``n ≥ 2``
    → pack up to ``n`` groups (clamped to what the lanes can hold).

    Returns ``{"g_b", "cin_lane", "n_sb"}``: groups per block (1 = off),
    each group's channel slot (`cin_g` padded to a power of two so blocks
    tile the 128 lanes evenly), and the superblock count
    ``ceil(groups / g_b)``.  The packed lane block is ``Lc = g_b *
    cin_lane`` wide; lane ``l`` belongs to group ``l // cin_lane`` — that
    integer map is the whole group-to-lane bookkeeping, recomputed by an
    iota inside the kernel.
    """
    off = dict(g_b=1, cin_lane=cin_g, n_sb=groups)
    if groups <= 1 or (lane_pack is not None and lane_pack <= 1):
        return off
    cin_lane = _next_pow2(cin_g)
    g_b = lanes // cin_lane if cin_lane <= lanes else 0
    if lane_pack is not None:
        g_b = min(g_b, lane_pack)
    g_b = min(g_b, groups)
    if g_b < 2:
        return off
    return dict(g_b=g_b, cin_lane=cin_lane, n_sb=-(-groups // g_b))


def lane_pack_codes(packed, groups: int, g_b: int, cin_lane: int):
    """packed [K, K, cin_g, Cout] → [n_sb, K*K, g_b*cin_lane, Cout//groups]
    int8 codes, lane-major within a superblock (lane ``g*cin_lane + i``
    holds group ``g``'s channel ``i``).  Padding — `cin_g` → `cin_lane`
    and `groups` → `n_sb*g_b` — uses int8 0, the dedicated zero code."""
    K1, K2, cin_g, Cout = packed.shape
    taps, cout_g = K1 * K2, Cout // groups
    n_sb = -(-groups // g_b)
    w = packed.reshape(taps, cin_g, groups, cout_g)
    w = jnp.pad(w, ((0, 0), (0, cin_lane - cin_g),
                    (0, n_sb * g_b - groups), (0, 0)))
    w = w.transpose(2, 0, 1, 3).reshape(n_sb, g_b, taps, cin_lane, cout_g)
    return w.transpose(0, 2, 1, 3, 4).reshape(n_sb, taps, g_b * cin_lane,
                                              cout_g)


def lane_unpack_codes(packed_lp, shape, groups: int, g_b: int,
                      cin_lane: int):
    """Inverse of `lane_pack_codes`: → the natural [K, K, cin_g, Cout]."""
    K1, K2, cin_g, Cout = shape
    taps, cout_g = K1 * K2, Cout // groups
    n_sb = packed_lp.shape[0]
    w = packed_lp.reshape(n_sb, taps, g_b, cin_lane, cout_g)
    w = w.transpose(0, 2, 1, 3, 4).reshape(n_sb * g_b, taps, cin_lane,
                                           cout_g)
    w = w[:groups, :, :cin_g, :]
    return w.transpose(1, 2, 0, 3).reshape(K1, K2, cin_g, Cout)


# ---------------------------------------------------------------------------
# fused implicit-im2col kernel
# ---------------------------------------------------------------------------


def _fit_dim(x, axis: int, size: int):
    """Pad with zeros or crop so ``x.shape[axis] == size`` (trailing edge)."""
    cur = x.shape[axis]
    if cur < size:
        pads = [(0, 0)] * x.ndim
        pads[axis] = (0, size - cur)
        return jnp.pad(x, pads)
    if cur > size:
        return jax.lax.slice_in_dim(x, 0, size, axis=axis)
    return x


def fused_conv_geometry(B: int, H: int, W: int, C: int, K: int, Cout: int,
                        *, stride: int = 1, padding="SAME", groups: int = 1,
                        block_cin: int = 128, block_cout: int = 128,
                        rows_per_tile: int | None = None,
                        batch_per_tile: int | None = None,
                        lane_pack: int | None = None) -> dict:
    """Resolve the fused kernel's tiling for one layer shape.

    Shared by the kernel itself, the autotuner's VMEM filter, and the
    analytic traffic model, so all three describe the same launch.

    When lane packing engages (``g_b > 1``), the channel axis is tiled by
    superblocks of ``g_b`` groups: ``bcin`` becomes the packed lane width
    ``Lc = g_b*cin_lane`` (one reduction block, ``ncb = 1``), the groups
    grid dimension shrinks to ``n_sb = ceil(groups/g_b)``, and each
    output block is ``ow = bcout*g_b`` channels wide (``bcout`` output
    channels for each of the block's groups, interleaved o-major).
    """
    pads = normalize_padding(padding, K, stride, H, W)
    Ho = _out_size(H, K, stride, pads[0])
    Wo = _out_size(W, K, stride, pads[1])
    cin_g, cout_g = C // groups, Cout // groups
    lp = lane_pack_geometry(groups, cin_g, lane_pack)
    g_b, cin_lane, n_sb = lp["g_b"], lp["cin_lane"], lp["n_sb"]
    rt = Ho if rows_per_tile is None else max(1, min(int(rows_per_tile), Ho))
    n_rt = -(-Ho // rt)
    bcout = max(1, min(block_cout, cout_g))
    cout_gp = _ceil_to(cout_g, bcout)
    if g_b > 1:
        bcin = cin_gp = g_b * cin_lane     # one packed lane block, ncb = 1
    else:
        bcin = max(1, min(block_cin, cin_g))
        cin_gp = _ceil_to(cin_g, bcin)
    rows_in = rt * stride + K - 1          # row tile + halo
    Wp = Wo * stride + K - 1
    Hp = n_rt * rt * stride + K - 1        # rows so every tile's halo exists
    BT = B * n_rt
    if batch_per_tile is None:
        # weight-stationary across batch (the paper's multi-threaded weight
        # broadcast): widen the batch tile while the slab fits ~4 MB VMEM
        per = max(rows_in * Wp * bcin * 4, 1)
        bt = max(1, min(BT, (4 << 20) // per))
    else:
        bt = max(1, min(int(batch_per_tile), BT))
    while BT % bt:
        bt -= 1
    return dict(pads=pads, Ho=Ho, Wo=Wo, cin_g=cin_g, cout_g=cout_g,
                rt=rt, n_rt=n_rt, bcin=bcin, bcout=bcout, cin_gp=cin_gp,
                cout_gp=cout_gp, rows_in=rows_in, Wp=Wp, Hp=Hp, BT=BT, bt=bt,
                ncb=cin_gp // bcin, njb=cout_gp // bcout, taps=K * K,
                g_b=g_b, cin_lane=cin_lane, n_sb=n_sb, ow=bcout * g_b)


def _fused_kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *,
                  cfg: LogQuantConfig, K: int, stride: int, bt: int, rt: int,
                  Wo: int, acc_dtype, g_b: int = 1, cin_lane: int = 0):
    c, t = pl.program_id(3), pl.program_id(4)

    @pl.when((c == 0) & (t == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # implicit im2col: slice tap (kh, kw) out of the VMEM-resident activation
    # slab — the slab itself was fetched once for this (tile, cin-block) and
    # is re-sliced for all K² taps (line-buffer reuse, no HBM patch blow-up).
    kh, kw = t // K, t % K
    SH, SW = rt * stride, Wo * stride
    xs = x_ref[:, pl.ds(kh, SH), pl.ds(kw, SW), :]       # [bt, SH, SW, bcin]
    if stride > 1:
        xs = xs.reshape(bt, rt, stride, Wo, stride, -1)[:, :, 0, :, 0, :]
    patch = xs.reshape(bt * rt * Wo, -1).astype(acc_dtype)

    # decode this tap's weight block next to the MXU (eq. 8 LUT+shift)
    w = _decode_block(w_ref[0, 0], cfg, acc_dtype)       # [bcin, bcout]
    if g_b > 1:
        # unpack the group-to-lane map next to the MXU: the compact block
        # serves g_b groups at once; lane l belongs to group l//cin_lane,
        # so output column (o, g) is masked to exactly its group's lanes
        # (out-of-group taps contribute an exact 0 to the contraction).
        Lc, bcout = w.shape
        lane_g = jax.lax.broadcasted_iota(jnp.int32, (Lc, g_b), 0) // cin_lane
        col_g = jax.lax.broadcasted_iota(jnp.int32, (Lc, g_b), 1)
        mask = (lane_g == col_g).astype(acc_dtype)       # [Lc, g_b]
        w = (w[:, :, None] * mask[:, None, :]).reshape(Lc, bcout * g_b)
    acc_ref[...] += jnp.dot(patch, w, preferred_element_type=acc_dtype)

    @pl.when((c == pl.num_programs(3) - 1) & (t == pl.num_programs(4) - 1))
    def _flush():
        out = acc_ref[...] * s_ref[0].astype(acc_dtype)
        o_ref[...] = out.reshape(bt, rt, Wo, 1, -1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "cfg", "stride", "padding", "groups", "interpret", "out_dtype",
    "block_cin", "block_cout", "rows_per_tile", "batch_per_tile",
    "lane_pack", "prepacked"))
def log_conv2d_fused_pallas(x, packed, scale,
                            cfg: LogQuantConfig = DEFAULT_CFG, *,
                            stride: int = 1, padding="SAME", groups: int = 1,
                            interpret: bool = False, out_dtype=None,
                            block_cin: int = 128, block_cout: int = 128,
                            rows_per_tile: int | None = None,
                            batch_per_tile: int | None = None,
                            lane_pack: int | None = None,
                            prepacked: bool = False):
    """Direct NHWC conv with VMEM patch extraction (implicit im2col).

    Grid: (batch·row tiles, group superblocks, cout blocks, cin blocks,
    K² taps) with the reduction (cin, tap) innermost — the activation
    slab's block index is constant across all taps, so it is fetched once
    per tile and reused K² times; weight codes stream as packed int8 and
    decode in VMEM; psums live in a VMEM scratch until the last reduction
    step.  Groups are a grid dimension: each step contracts only its
    group's `cin_g` slice.  Block sizes are the autotuner's knobs.

    ``lane_pack`` (see `lane_pack_geometry`) packs ``g_b`` narrow groups
    into one 128-lane channel block: the groups grid dimension collapses
    by ``g_b``, the compact weight block decodes once and is broadcast-
    masked to its block-diagonal form *inside the kernel* (out-of-group
    taps contract as exact zeros), and each MXU pass produces ``g_b``
    groups' outputs — recovering up to 128× lane density for depthwise
    convs on real TPUs.  ``None`` auto-packs grouped shapes; ``1``
    forces the padded per-group path.  ``prepacked=True`` means `packed`
    is already in the `lane_pack_codes` layout
    ``[n_sb, K*K, g_b*cin_lane, cout_g]`` (the `QuantizedTensor`
    ``"lane_packed"`` serving layout), skipping the per-call rearrange.
    """
    if prepacked:
        assert lane_pack is not None and lane_pack > 1, \
            "prepacked codes require the matching lane_pack factor"
        B, H, W, C = x.shape
        K = int(round(packed.shape[1] ** 0.5))
        cout_g = packed.shape[-1]
        Cout = groups * cout_g
        assert C % groups == 0, (x.shape, groups)
    else:
        B, H, W, C, K, Cout = _check_shapes(x, packed, groups)
    g = fused_conv_geometry(
        B, H, W, C, K, Cout, stride=stride, padding=padding, groups=groups,
        block_cin=block_cin, block_cout=block_cout,
        rows_per_tile=rows_per_tile, batch_per_tile=batch_per_tile,
        lane_pack=lane_pack)
    G, taps = groups, g["taps"]
    (ph0, _), (pw0, _) = g["pads"]
    Ho, Wo, rt, n_rt, bt = g["Ho"], g["Wo"], g["rt"], g["n_rt"], g["bt"]
    cin_g, cout_g, cin_gp, cout_gp = (g["cin_g"], g["cout_g"], g["cin_gp"],
                                      g["cout_gp"])
    bcin, bcout, ncb, njb = g["bcin"], g["bcout"], g["ncb"], g["njb"]
    rows_in, Wp, Hp, BT = g["rows_in"], g["Wp"], g["Hp"], g["BT"]
    g_b, cin_lane, n_sb, ow = g["g_b"], g["cin_lane"], g["n_sb"], g["ow"]
    if prepacked:
        assert g_b == lane_pack and packed.shape == (n_sb, taps,
                                                     g_b * cin_lane, cout_g), \
            (packed.shape, (n_sb, taps, g_b * cin_lane, cout_g))

    # pad lead edges, then fit the trailing edge to the tiled extent (extra
    # zero rows/cols are only read into discarded stride phases)
    xp = jnp.pad(x, ((0, 0), (ph0, 0), (pw0, 0), (0, 0)))
    xp = _fit_dim(_fit_dim(xp, 1, Hp), 2, Wp)
    if g_b > 1:
        # lane-packed: pad each group's channels to its cin_lane slot and
        # the group count to whole superblocks — channel l of superblock
        # sb is group (sb*g_b + l//cin_lane), matching the weight lanes
        x5 = xp.reshape(B, Hp, Wp, G, cin_g)
        x5 = jnp.pad(x5, ((0, 0),) * 3 + ((0, n_sb * g_b - G),
                                          (0, cin_lane - cin_g)))
        xp = x5.reshape(B, Hp, Wp, n_sb * cin_gp)
    elif cin_gp != cin_g:
        x5 = xp.reshape(B, Hp, Wp, G, cin_g)
        x5 = jnp.pad(x5, ((0, 0),) * 4 + ((0, cin_gp - cin_g),))
        xp = x5.reshape(B, Hp, Wp, G * cin_gp)
    if n_rt == 1:
        xrt = xp                                  # rows_in == Hp
    else:
        # overlapping row tiles: duplicates only the (K-1)-row halo in HBM
        tiles = [jax.lax.slice_in_dim(xp, i * rt * stride,
                                      i * rt * stride + rows_in, axis=1)
                 for i in range(n_rt)]
        xrt = jnp.stack(tiles, axis=1).reshape(BT, rows_in, Wp, -1)

    # weights, still int8 (padding uses code 0, the dedicated zero code):
    #   padded path:      [K, K, cin_g, Cout] → [G, taps, cin_gp, cout_gp]
    #   lane-packed path: `lane_pack_codes` → [n_sb, taps, Lc, cout_gp]
    if g_b > 1:
        w = packed if prepacked else lane_pack_codes(packed, G, g_b,
                                                     cin_lane)
        w = jnp.pad(w, ((0, 0),) * 3 + ((0, cout_gp - cout_g),))
    else:
        w = packed.reshape(taps, cin_g, G, cout_g)
        w = jnp.pad(w, ((0, 0), (0, cin_gp - cin_g), (0, 0),
                        (0, cout_gp - cout_g)))
        w = w.transpose(2, 0, 1, 3)

    # scales per superblock, column-matched to the kernel's (o, g) output
    # interleave: column o*g_b + g scales group (sb*g_b + g)'s channel o
    s = jnp.broadcast_to(jnp.asarray(scale, jnp.float32).reshape(-1), (Cout,))
    s = jnp.pad(s.reshape(G, cout_g), ((0, n_sb * g_b - G),
                                       (0, cout_gp - cout_g)))
    s = s.reshape(n_sb, g_b, cout_gp).transpose(0, 2, 1)
    s = s.reshape(n_sb, cout_gp * g_b)

    acc_dtype = jnp.float32
    out = pl.pallas_call(
        functools.partial(_fused_kernel, cfg=cfg, K=K, stride=stride, bt=bt,
                          rt=rt, Wo=Wo, acc_dtype=acc_dtype, g_b=g_b,
                          cin_lane=cin_lane),
        grid=(BT // bt, n_sb, njb, ncb, taps),
        in_specs=[
            pl.BlockSpec((bt, rows_in, Wp, bcin),
                         lambda bi, gg, j, c, t: (bi, 0, 0, gg * ncb + c)),
            pl.BlockSpec((1, 1, bcin, bcout),
                         lambda bi, gg, j, c, t: (gg, t, c, j)),
            pl.BlockSpec((1, ow), lambda bi, gg, j, c, t: (gg, j)),
        ],
        out_specs=pl.BlockSpec((bt, rt, Wo, 1, ow),
                               lambda bi, gg, j, c, t: (bi, 0, 0, gg, j)),
        out_shape=jax.ShapeDtypeStruct((BT, rt, Wo, n_sb, cout_gp * g_b),
                                       out_dtype or x.dtype),
        scratch_shapes=[pltpu.VMEM((bt * rt * Wo, ow), acc_dtype)],
        interpret=interpret,
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary", "arbitrary")),
    )(xrt, w, s)
    # unscramble: [.., n_sb, (o, g)] → group-major channels, crop padding
    out = out.reshape(B, n_rt * rt, Wo, n_sb, cout_gp, g_b)[:, :Ho]
    out = out.transpose(0, 1, 2, 3, 5, 4).reshape(B, Ho, Wo, n_sb * g_b,
                                                  cout_gp)
    return out[:, :, :, :G, :cout_g].reshape(B, Ho, Wo, Cout)


# ---------------------------------------------------------------------------
# analytic HBM-traffic model (reported per impl by benchmarks/conv_kernels)
# ---------------------------------------------------------------------------


def conv_traffic_bytes(impl: str, B: int, H: int, W: int, C: int, K: int,
                       Cout: int, *, stride: int = 1, padding="SAME",
                       groups: int = 1, act_itemsize: int = 4,
                       code_itemsize: int = 1, config: dict | None = None,
                       matmul_block: int = 128, lanes: int = 1) -> dict:
    """Bytes moved HBM↔VMEM for one conv call, per implementation.

    First-order model: counts every block fetch/spill the grid actually
    performs (patch materialisation write+read, per-output-block activation
    re-reads, per-tile weight re-reads) and ignores sub-block padding waste.
    Returns ``{"act": ..., "w": ..., "out": ..., "act_w": ..., "total": ...}``.

    ``lanes`` models the physical lane width of the fused path's channel
    blocks: a real TPU DMAs (and contracts) whole 128-lane blocks, so a
    grouped conv's per-group `cin` block costs ``ceil_to(bcin, lanes)``
    channels no matter how narrow the group.  The default ``lanes=1`` is
    the pure byte count (backend-independent, what the 3×3 acceptance
    gates); ``lanes=128`` is the hardware-honest figure the lane-packed
    bench rows compare.  Fused rows also carry ``lane_density`` — useful
    contraction lanes over fetched 128-lane capacity, the utilization the
    lane-packed layout recovers (reported per dispatch by
    `obs/kernel_profile.py`).
    """
    pads = normalize_padding(padding, K, stride, H, W)
    Ho, Wo = _out_size(H, K, stride, pads[0]), _out_size(W, K, stride, pads[1])
    cin_g = C // groups
    x_b = B * H * W * C * act_itemsize
    out_b = B * Ho * Wo * Cout * act_itemsize
    w_codes = K * K * cin_g * Cout * code_itemsize
    density = None

    if impl == "fp32":
        act, w = x_b, K * K * cin_g * Cout * act_itemsize
    elif impl == "blockwise":
        act, w = x_b, w_codes
    elif impl == "pallas_im2col":
        # patches hit HBM: K² tap-slice reads of x, one write, then one read
        # per output-channel block of the matmul; weights are block-diagonal
        # (×groups) and re-read per M block.
        patch_b = B * Ho * Wo * K * K * C * act_itemsize
        n_j = -(-Cout // matmul_block)
        n_i = -(-(B * Ho * Wo) // matmul_block)
        act = patch_b * (2 + n_j)
        w = K * K * groups * cin_g * Cout * code_itemsize * n_i
    elif impl in ("pallas", "pallas_fused"):
        g = fused_conv_geometry(B, H, W, C, K, Cout, stride=stride,
                                padding=padding, groups=groups,
                                **(config or {}))
        n_bt = g["BT"] // g["bt"]
        # fetched channel width per (superblock, reduction step), padded to
        # whole physical lane blocks; g_b=1 ⇒ n_sb=groups, bcin·ncb=cin_gp
        ch = g["n_sb"] * g["ncb"] * _ceil_to(g["bcin"], lanes)
        act = (n_bt * g["bt"] * g["rows_in"] * g["Wp"] * ch
               * act_itemsize * g["njb"])
        w = (g["n_sb"] * g["taps"] * g["ncb"] * _ceil_to(g["bcin"], lanes)
             * g["cout_gp"] * code_itemsize * n_bt)
        density = (groups * cin_g) / (g["n_sb"] * g["ncb"]
                                      * _ceil_to(g["bcin"], LANES))
    else:
        raise ValueError(f"unknown impl {impl!r}")
    out = {"act": int(act), "w": int(w), "out": int(out_b),
           "act_w": int(act + w), "total": int(act + w + out_b)}
    if density is not None:
        out["lane_density"] = round(min(density, 1.0), 4)
    return out


def log_conv2d_ref(x, packed, scale, cfg: LogQuantConfig = DEFAULT_CFG,
                   *, stride: int = 1, padding="SAME", groups: int = 1,
                   out_dtype=None):
    """Full-materialisation oracle: explicit patches × `ref_log_matmul`."""
    B, H, W, C, K, Cout = _check_shapes(x, packed, groups)
    pads = normalize_padding(padding, K, stride, H, W)
    patches, Ho, Wo = _im2col(x.astype(jnp.float32), K, stride, pads)
    codes = _block_diag_codes(packed, groups)
    scale = jnp.broadcast_to(jnp.asarray(scale, jnp.float32).reshape(1, -1),
                             (1, Cout))
    out = ref_log_matmul(patches.reshape(B * Ho * Wo, -1), codes, scale, cfg,
                         out_dtype=out_dtype or x.dtype)
    return out.reshape(B, Ho, Wo, Cout)
