"""NHWC conv2d against packed 6-bit(+sign) log-quantized weights.

This is the conv realisation of the NeuroMAX log-PE + 2D weight-broadcast
dataflow on TPU, and the middle of the repo's three-tier conv stack:

    kernels/log_conv2d.py  (this file, Pallas + blockwise + ref)
        ↕  numerics cross-checked in tests/test_conv2d.py
    core/pe_grid.py        (cycle-accurate 6×3×6 PE-grid hardware oracle)

Three implementations share one contract (see `kernels/ops.conv2d` for the
dispatch layer):

  * ``log_conv2d_pallas`` — im2col patch tiling lowered onto the existing
    `log_matmul_pallas` MXU kernel: weight codes stay int8 in HBM, are
    decoded in VMEM next to the MXU (eq. 8's LUT+shift as `exp2` of a
    half-integer), and psums never leave the accumulator — the §5 weight
    broadcast mapped onto TPU tiling.  Grouped convs (MobileNet dwconv)
    are lowered as a block-diagonal code matrix: out-of-group entries hold
    the dedicated zero code, which decodes to an exact 0.0, so a single
    MXU pass computes every group at once (bytes ×groups, a documented
    trade for one kernel launch instead of `groups`).
  * ``log_conv2d_blockwise`` — decode-then-`lax.conv` in jnp.  XLA fuses the
    int8→float decode into the convolution's weight operand, so the weight
    bytes that move stay int8 (same memory behaviour as the kernel); this
    is what model lowering uses on every backend without Pallas.
  * ``log_conv2d_ref`` — full-materialisation oracle: explicit im2col
    patches against `ref.ref_log_matmul` at highest precision.  Independent
    of `lax.conv`, so it cross-validates the patch extraction itself.

All three take the same packed layout: ``packed [K, K, Cin//groups, Cout]``
int8 codes with a per-output-channel (or scalar) fp scale, `stride`,
`padding` ("SAME"/"VALID"/int/explicit pairs) and `groups`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.logquant import LogQuantConfig, log_dequantize
from .log_matmul import log_matmul_pallas
from .ref import ref_log_matmul

DEFAULT_CFG = LogQuantConfig()


# ---------------------------------------------------------------------------
# shared plumbing
# ---------------------------------------------------------------------------


def _pad_pair(size: int, k: int, stride: int) -> tuple[int, int]:
    """XLA-style SAME padding for one spatial dim."""
    out = -(-size // stride)
    total = max((out - 1) * stride + k - size, 0)
    return total // 2, total - total // 2


def normalize_padding(padding, K: int, stride: int, H: int, W: int):
    """→ ((lo_h, hi_h), (lo_w, hi_w)), accepting SAME/VALID/int/pairs."""
    if isinstance(padding, str):
        p = padding.upper()
        if p == "VALID":
            return (0, 0), (0, 0)
        if p == "SAME":
            return _pad_pair(H, K, stride), _pad_pair(W, K, stride)
        raise ValueError(f"unknown padding {padding!r}")
    if isinstance(padding, int):
        return (padding, padding), (padding, padding)
    (ph, pw) = padding
    if isinstance(ph, int):
        return (ph, ph), (pw, pw)
    return tuple(ph), tuple(pw)


def _out_size(size: int, k: int, stride: int, pads: tuple[int, int]) -> int:
    return (size + pads[0] + pads[1] - k) // stride + 1


def _im2col(x, K: int, stride: int, pads):
    """x: [B, H, W, C] → patches [B, Ho, Wo, K*K*C], tap-major (kh, kw, c).

    The tap ordering matches ``w.reshape(K*K*Cin, Cout)`` of an HWIO kernel,
    so a plain matmul against the reshaped weight is the convolution.
    """
    B, H, W, C = x.shape
    (ph0, ph1), (pw0, pw1) = pads
    xp = jnp.pad(x, ((0, 0), (ph0, ph1), (pw0, pw1), (0, 0)))
    Ho = _out_size(H, K, stride, (ph0, ph1))
    Wo = _out_size(W, K, stride, (pw0, pw1))
    taps = []
    for kh in range(K):
        for kw in range(K):
            taps.append(jax.lax.slice(
                xp, (0, kh, kw, 0),
                (B, kh + (Ho - 1) * stride + 1, kw + (Wo - 1) * stride + 1, C),
                (1, stride, stride, 1)))
    patches = jnp.stack(taps, axis=3)            # [B, Ho, Wo, K*K, C]
    return patches.reshape(B, Ho, Wo, K * K * C), Ho, Wo


def _block_diag_codes(packed, groups: int):
    """packed [K, K, cin_g, Cout] → [K*K*(groups·cin_g), Cout] block-diagonal
    int8 codes: row (tap, g, i) holds the code for output channels of group
    g only; everywhere else the zero code (int8 0), which decodes to 0.0."""
    K1, K2, cin_g, Cout = packed.shape
    cout_g = Cout // groups
    taps = K1 * K2
    w = packed.reshape(taps, cin_g, Cout)
    if groups == 1:
        return w.reshape(taps * cin_g, Cout)
    group_of_out = jnp.arange(Cout) // cout_g                 # [Cout]
    in_group = group_of_out[None, :] == jnp.arange(groups)[:, None]
    # [taps, g, i, o] — keep codes only where o belongs to group g
    wbd = w[:, None, :, :] * in_group[None, :, None, :].astype(packed.dtype)
    return wbd.reshape(taps * groups * cin_g, Cout)


def _check_shapes(x, packed, groups):
    B, H, W, C = x.shape
    K1, K2, cin_g, Cout = packed.shape
    assert K1 == K2, f"square kernels only, got {K1}x{K2}"
    assert C == cin_g * groups, (x.shape, packed.shape, groups)
    assert Cout % groups == 0, (Cout, groups)
    return B, H, W, C, K1, Cout


# ---------------------------------------------------------------------------
# the three implementations
# ---------------------------------------------------------------------------


def log_conv2d_pallas(x, packed, scale, cfg: LogQuantConfig = DEFAULT_CFG,
                      *, stride: int = 1, padding="SAME", groups: int = 1,
                      interpret: bool = False, out_dtype=None):
    """Packed-weight conv on the `log_matmul_pallas` MXU path via im2col."""
    B, H, W, C, K, Cout = _check_shapes(x, packed, groups)
    pads = normalize_padding(padding, K, stride, H, W)
    patches, Ho, Wo = _im2col(x, K, stride, pads)
    codes = _block_diag_codes(packed, groups)
    scale = jnp.broadcast_to(jnp.asarray(scale, jnp.float32).reshape(1, -1),
                             (1, Cout))
    out = log_matmul_pallas(patches.reshape(B * Ho * Wo, -1), codes, scale,
                            cfg, interpret=interpret,
                            out_dtype=out_dtype or x.dtype)
    return out.reshape(B, Ho, Wo, Cout)


def log_conv2d_blockwise(x, packed, scale, cfg: LogQuantConfig = DEFAULT_CFG,
                         *, stride: int = 1, padding="SAME", groups: int = 1,
                         out_dtype=None):
    """Decode-then-conv fallback; XLA keeps the moved weight bytes int8."""
    B, H, W, C, K, Cout = _check_shapes(x, packed, groups)
    pads = normalize_padding(padding, K, stride, H, W)
    scale = jnp.asarray(scale, jnp.float32).reshape(1, -1)
    w = log_dequantize(packed, scale.reshape(1, 1, 1, -1), cfg,
                       dtype=jnp.float32)
    y = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w, window_strides=(stride, stride),
        padding=pads, dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    return y.astype(out_dtype or x.dtype)


def log_conv2d_ref(x, packed, scale, cfg: LogQuantConfig = DEFAULT_CFG,
                   *, stride: int = 1, padding="SAME", groups: int = 1,
                   out_dtype=None):
    """Full-materialisation oracle: explicit patches × `ref_log_matmul`."""
    B, H, W, C, K, Cout = _check_shapes(x, packed, groups)
    pads = normalize_padding(padding, K, stride, H, W)
    patches, Ho, Wo = _im2col(x.astype(jnp.float32), K, stride, pads)
    codes = _block_diag_codes(packed, groups)
    scale = jnp.broadcast_to(jnp.asarray(scale, jnp.float32).reshape(1, -1),
                             (1, Cout))
    out = ref_log_matmul(patches.reshape(B * Ho * Wo, -1), codes, scale, cfg,
                         out_dtype=out_dtype or x.dtype)
    return out.reshape(B, Ho, Wo, Cout)
