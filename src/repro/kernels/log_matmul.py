"""Pallas TPU kernel: matmul against 6-bit(+sign) log-quantized weights.

This is the TPU-native realisation of the NeuroMAX PE (paper §4) + 2D
weight-broadcast dataflow (§5):

  * Weights live in HBM as packed int8 log codes (sign in bit 6, biased
    base-√2 exponent in bits 0-5) — 2.67× fewer weight bytes than bf16, the
    same saving the paper gets on DDR traffic and SRAM.
  * Each grid step loads one (bk × bn) code block into VMEM **once** and
    broadcasts it across the whole (bm) activation block — the weight-
    stationary "2D broadcast" of §5 mapped onto VMEM tiling.
  * The decode is eq. (8) vectorised: sign · 2^(code/2).  On the VPU
    `exp2` of a half-integer is exactly the LUT(FRAC)·2^INT decomposition
    (2-entry LUT × barrel shift); the MXU then plays the role of the
    108-PE grid + adder nets, accumulating psums in a VMEM scratch so they
    never travel to HBM (the paper's "only 11 % of psums stored" property —
    here it is 0 %: psums stay in the accumulator until the final k step).

Block shapes default to MXU-aligned (128) multiples.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.logquant import LogQuantConfig
from ._compat import TPUCompilerParams

DEFAULT_CFG = LogQuantConfig()


def _decode_block(codes, cfg: LogQuantConfig, dtype):
    """Vectorised eq. (8): packed int8 → float block (VPU LUT+shift)."""
    p = codes.astype(jnp.int32)
    mask = (1 << cfg.bits) - 1
    biased = p & mask
    sign = 1.0 - 2.0 * ((p >> cfg.bits) & 1).astype(dtype)
    code = (biased - cfg.bias).astype(dtype)
    mag = jnp.exp2(code / cfg.steps)
    nonzero = (biased != cfg.zero_code).astype(dtype)
    return sign * mag * nonzero


def _log_matmul_kernel(x_ref, w_ref, scale_ref, o_ref, acc_ref, *,
                       cfg: LogQuantConfig, acc_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # decode the weight block in VMEM (weight-stationary broadcast), then MXU
    w = _decode_block(w_ref[...], cfg, acc_dtype)
    acc_ref[...] += jnp.dot(x_ref[...].astype(acc_dtype), w,
                            preferred_element_type=acc_dtype)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        # per-output-channel scale applied once at psum flush (post-processing
        # block of Fig. 2); psums never left VMEM.
        o_ref[...] = (acc_ref[...] * scale_ref[...].astype(acc_dtype)
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("cfg", "block_m", "block_k",
                                             "block_n", "interpret",
                                             "out_dtype"))
def log_matmul_pallas(x, packed, scale, cfg: LogQuantConfig = DEFAULT_CFG,
                      block_m: int = 128, block_k: int = 128,
                      block_n: int = 128, interpret: bool = False,
                      out_dtype=None):
    """x: [M, K] float; packed: [K, N] int8 codes; scale: [1, N] or [] float.

    Shapes need not be block-aligned; we pad (zero codes decode to 0.0, so
    padding contributes nothing).
    """
    M, K = x.shape
    K2, N = packed.shape
    assert K == K2, (x.shape, packed.shape)
    out_dtype = out_dtype or x.dtype
    scale = jnp.broadcast_to(jnp.asarray(scale, jnp.float32), (1, N))

    pm, pk, pn = (-M) % block_m, (-K) % block_k, (-N) % block_n
    xp = jnp.pad(x, ((0, pm), (0, pk)))
    wp = jnp.pad(packed, ((0, pk), (0, pn)))  # code 0 ≡ exact zero
    sp = jnp.pad(scale, ((0, 0), (0, pn)))
    Mp, Kp, Np = M + pm, K + pk, N + pn

    acc_dtype = jnp.float32
    out = pl.pallas_call(
        functools.partial(_log_matmul_kernel, cfg=cfg, acc_dtype=acc_dtype),
        grid=(Mp // block_m, Np // block_n, Kp // block_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), acc_dtype)],
        interpret=interpret,
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(xp, wp, sp)
    return out[:M, :N]
