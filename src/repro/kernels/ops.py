"""Jit'd public wrappers around the Pallas kernels, with pure-jnp fallbacks.

Dispatch policy (`impl=`):
  "pallas"    — the Pallas kernel (TPU; `interpret=True` executes on CPU)
  "blockwise" — pure-jnp blockwise/chunked math (same memory behaviour under
                XLA; this is what model lowering uses on every backend)
  "ref"       — full-materialisation oracle (small shapes / tests)
  "auto"      — pallas on TPU, blockwise elsewhere
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.logquant import (LogQuantConfig, QuantizedTensor,
                                 quantize_tensor)
from . import autotune as _autotune
from . import ref as _ref
from .flash_attention import flash_attention_pallas
from .log_conv2d import (log_conv2d_blockwise, log_conv2d_fused_pallas,
                         log_conv2d_pallas, log_conv2d_ref)
from .log_matmul import log_matmul_pallas
from .wkv6 import wkv6_chunked_jnp, wkv6_pallas


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "blockwise"
    if impl not in ("pallas", "blockwise", "ref"):
        raise ValueError(f"unknown impl {impl!r}; "
                         f"expected pallas|blockwise|ref|auto")
    return impl


# ---------------------------------------------------------------------------
# log_matmul
# ---------------------------------------------------------------------------


def log_matmul(x, qt: QuantizedTensor, *, impl: str = "auto",
               interpret: bool | None = None):
    """x: [..., K] @ dequant(qt [K, N]) → [..., N]."""
    impl = _resolve(impl)
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    scale = jnp.broadcast_to(jnp.asarray(qt.scale, jnp.float32),
                             (1, qt.packed.shape[-1]))
    if impl == "pallas":
        interp = (not _on_tpu()) if interpret is None else interpret
        out = log_matmul_pallas(x2, qt.packed, scale, qt.cfg,
                                interpret=interp, out_dtype=x.dtype)
    else:
        # blockwise == ref for a matmul: XLA fuses decode into the dot's
        # operand; weight bytes moved stay int8.
        out = _ref.ref_log_matmul(x2, qt.packed, scale, qt.cfg,
                                  out_dtype=x.dtype)
    return out.reshape(*lead, -1)


# ---------------------------------------------------------------------------
# conv2d — the unified log-domain conv dispatch layer
# ---------------------------------------------------------------------------


_CONV_IMPLS = ("pallas", "pallas_im2col", "blockwise", "ref")


def _resolve_conv(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "blockwise"
    if impl not in _CONV_IMPLS:
        raise ValueError(f"unknown conv impl {impl!r}; expected "
                         f"pallas|pallas_im2col|blockwise|ref|auto")
    return impl


def _hashable_padding(padding):
    if isinstance(padding, (list, tuple)):
        return tuple(tuple(p) if isinstance(p, (list, tuple)) else p
                     for p in padding)
    return padding


def conv2d(x, qt, *, stride: int = 1, padding="SAME", groups: int = 1,
           impl: str = "auto", interpret: bool | None = None,
           out_dtype=None, qcfg: LogQuantConfig | None = None,
           config: dict | None = None, autotune: bool = False):
    """x: [B, H, W, Cin] ⊛ dequant(qt [K, K, Cin//groups, Cout]) → NHWC out.

    The single entry point of the three-tier conv stack (see
    `kernels/log_conv2d.py`): ``impl="pallas"`` is the fused
    implicit-im2col kernel (block sizes from the autotuner's on-disk table
    when present, heuristics otherwise; ``config=`` overrides,
    ``autotune=True`` measures candidates for this shape first and
    persists the winner), ``"pallas_im2col"`` the explicit-im2col
    fallback on `log_matmul_pallas`, ``"blockwise"`` the jnp fallback,
    ``"ref"`` the full-materialisation oracle; `auto` means pallas on TPU
    and blockwise elsewhere.  `qt` is a `QuantizedTensor` of packed log
    codes (per-output-channel scales supported; the serving-time
    ``layout="conv_taps"`` pre-reshape is accepted); a plain float array
    is packed on the fly as a convenience (inference only — quantization
    is not differentiable).  Supports stride, SAME/VALID/explicit padding,
    and grouped/depthwise convs (``groups=Cin``).
    """
    if not isinstance(qt, QuantizedTensor):
        qt = quantize_tensor(jnp.asarray(qt), qcfg or LogQuantConfig())
    packed = qt.packed
    if getattr(qt, "layout", None) == "conv_taps":
        packed = packed.reshape(qt.shape)  # [taps, cin_g, Cout] → 4-D HWIO
    assert packed.ndim == 4, f"conv weights must be [K,K,Cin_g,Cout], " \
        f"got {packed.shape}"
    impl = _resolve_conv(impl)
    padding = _hashable_padding(padding)
    kw = dict(stride=stride, padding=padding, groups=groups,
              out_dtype=out_dtype)
    if impl in ("pallas", "pallas_im2col"):
        interp = (not _on_tpu()) if interpret is None else interpret
        if impl == "pallas_im2col":
            return log_conv2d_pallas(x, packed, qt.scale, qt.cfg,
                                     interpret=interp, **kw)
        B, H, W, C = x.shape
        K, Cout = packed.shape[0], packed.shape[-1]
        shape_kw = dict(stride=stride, padding=padding, groups=groups)
        if config is None and autotune:
            config = _autotune.autotune_conv2d(
                x, packed, qt.scale, qt.cfg, interpret=interp, **shape_kw)
        if config is None:
            key = _autotune.conv_key(
                B, H, W, C, K, Cout, cfg=qt.cfg, **shape_kw,
                backend=("interpret" if interp else None))
            config = _autotune.lookup(key) or _autotune.default_config(
                B, H, W, C, K, Cout, **shape_kw)
        return log_conv2d_fused_pallas(x, packed, qt.scale, qt.cfg,
                                       interpret=interp, **kw, **config)
    if impl == "ref":
        return log_conv2d_ref(x, packed, qt.scale, qt.cfg, **kw)
    return log_conv2d_blockwise(x, packed, qt.scale, qt.cfg, **kw)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def _blockwise_attention(q, k, v, *, causal, window, scale, q_offset,
                         k_offset=0, block_k: int = 1024,
                         acc_dtype=jnp.float32, gqa_broadcast: bool = False):
    """Online-softmax over kv blocks with lax.scan — O(Tq·bk) live memory.

    q: [B, Tq, H, D]; k, v: [B, Tk, Hkv, D].

    §Perf knobs: `acc_dtype` runs the score/accumulator math in bf16
    (running max/sum stay f32 for stability); `gqa_broadcast` reshapes q to
    [B,Tq,Hkv,rep,D] and contracts against unexpanded K/V instead of
    materialising rep× repeated K/V blocks."""
    B, Tq, H, D = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    f32 = jnp.float32
    cdt = acc_dtype

    pk = (-Tk) % block_k
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nkv = (Tk + pk) // block_k
    # [nkv, B, bk, Hkv, D]
    kc = kp.reshape(B, nkv, block_k, Hkv, D).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(B, nkv, block_k, Hkv, D).transpose(1, 0, 2, 3, 4)

    use_bcast = gqa_broadcast and rep > 1
    qf = (q.astype(cdt) * jnp.asarray(scale, cdt))
    if use_bcast:
        qf = qf.reshape(B, Tq, Hkv, rep, D)
    qpos = jnp.arange(Tq) + q_offset

    def step(carry, inp):
        m, l, acc = carry                 # [B,H,Tq,1], [B,H,Tq,1], [B,H,Tq,D]
        kb, vb, kv_idx = inp
        if use_bcast:
            # s: [B, Hkv, rep, Tq, bk] without expanding K
            s = jnp.einsum("bqhrd,bkhd->bhrqk", qf, kb.astype(cdt))
            s = s.reshape(B, H, Tq, block_k)
        else:
            if rep > 1:
                kb = jnp.repeat(kb, rep, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb.astype(cdt))
        s = s.astype(f32)
        kpos = kv_idx * block_k + jnp.arange(block_k) + k_offset
        mask = (kpos[None, :] < Tk + k_offset) & (kpos[None, :] >= 0)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        s = jnp.where(mask[None, None], s, -1e30)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.where(mask[None, None], jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        if use_bcast:
            pv = jnp.einsum("bhrqk,bkhd->bqhrd",
                            p.reshape(B, Hkv, rep, Tq, block_k).astype(cdt),
                            vb.astype(cdt))
            pv = pv.reshape(B, Tq, H, D).transpose(0, 2, 1, 3)
        else:
            vb_ = jnp.repeat(vb, rep, axis=2) if rep > 1 else vb
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(cdt),
                            vb_.astype(cdt))
        acc = alpha * acc + pv.astype(f32)
        return (m_new, l, acc), None

    init = (jnp.full((B, H, Tq, 1), -1e30, f32),
            jnp.zeros((B, H, Tq, 1), f32),
            jnp.zeros((B, H, Tq, D), f32))
    (m, l, acc), _ = jax.lax.scan(step, init,
                                  (kc, vc, jnp.arange(nkv)))
    out = acc / jnp.where(l > 0, l, 1.0)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              scale=None, q_offset: int = 0, k_offset=0, impl: str = "auto",
              block_k: int = 1024, interpret: bool | None = None,
              acc_dtype=jnp.float32, gqa_broadcast: bool = False):
    """GQA attention.  q: [B, Tq, H, D]; k, v: [B, Tk, Hkv, D].

    q_offset/k_offset may be traced scalars (decode); the Pallas path
    requires static offsets, so dynamic-offset calls dispatch to blockwise.
    """
    impl = _resolve(impl)
    dynamic = not (isinstance(q_offset, int) and isinstance(k_offset, int))
    if impl == "pallas" and (dynamic or k_offset != 0):
        impl = "blockwise"
    if impl == "ref":
        return _ref.ref_attention(q, k, v, causal=causal, window=window,
                                  scale=scale, q_offset=q_offset,
                                  k_offset=k_offset)
    if impl == "blockwise":
        return _blockwise_attention(q, k, v, causal=causal, window=window,
                                    scale=scale, q_offset=q_offset,
                                    k_offset=k_offset, block_k=block_k,
                                    acc_dtype=acc_dtype,
                                    gqa_broadcast=gqa_broadcast)
    # pallas: fold GQA + batch into BH
    B, Tq, H, D = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qq = q.transpose(0, 2, 1, 3).reshape(B * H, Tq, D)
    kk = k.transpose(0, 2, 1, 3).reshape(B * H, -1, D)
    vv = v.transpose(0, 2, 1, 3).reshape(B * H, -1, D)
    interp = (not _on_tpu()) if interpret is None else interpret
    bq = min(128, max(16, Tq))
    out = flash_attention_pallas(qq, kk, vv, causal=causal, window=window,
                                 scale=scale, q_offset=q_offset,
                                 block_q=bq, block_k=min(128, kk.shape[1]),
                                 interpret=interp)
    return out.reshape(B, H, Tq, D).transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------


def wkv6(r, k, v, logw, u, state=None, *, impl: str = "auto", chunk: int = 64,
         interpret: bool | None = None):
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.ref_wkv6(r, k, v, logw, u, state)
    if impl == "blockwise":
        return wkv6_chunked_jnp(r, k, v, logw, u, state, chunk=chunk)
    interp = (not _on_tpu()) if interpret is None else interpret
    return wkv6_pallas(r, k, v, logw, u, state, chunk=chunk, interpret=interp)
