"""Jit'd public wrappers around the Pallas kernels, with pure-jnp fallbacks.

The unified kernel-call surface.  Every public op takes the same trio of
dispatch knobs, resolved by `resolve_impl` with one precedence order:

  impl=       "pallas" | "blockwise" | "ref" | "auto" (+ op-specific
              aliases, e.g. conv2d's "pallas_im2col").  "auto" → pallas
              on TPU, blockwise elsewhere.
  config=     a per-op frozen config dataclass (`AttentionConfig`,
              `ConvConfig`, `WkvConfig`) holding block sizes / math
              knobs.  Fields left at None are filled from the autotune
              table (`kernels/autotune.py`) when an entry exists for the
              shape, else from per-op heuristics.
  interpret=  None → interpret off-TPU (so Pallas kernels run anywhere);
              an explicit bool always wins.

Plus ``autotune=True`` on the tiled kernels (conv2d, attention) to
measure candidates for the call's shape first and persist the winner.

Implementations per op:
  "pallas"    — the Pallas kernel (TPU; `interpret=True` executes on CPU)
  "blockwise" — pure-jnp blockwise/chunked math (same memory behaviour under
                XLA; this is what model lowering uses on every backend)
  "ref"       — full-materialisation oracle (small shapes / tests)
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.logquant import (LogQuantConfig, QuantizedTensor,
                                 quantize_tensor)
from repro.obs import kernel_profile as _kprof
from . import autotune as _autotune
from . import ref as _ref
from .flash_attention import attention_traffic_bytes, flash_attention_pallas
from .log_conv2d import (conv_traffic_bytes, lane_unpack_codes,
                         log_conv2d_blockwise, log_conv2d_fused_pallas,
                         log_conv2d_pallas, log_conv2d_ref)
from .log_matmul import log_matmul_pallas
from .wkv6 import wkv6_chunked_jnp, wkv6_pallas


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:  # pragma: no cover
        return False


_OP_IMPLS = {
    "log_matmul": ("pallas", "blockwise", "ref"),
    "conv2d": ("pallas", "pallas_im2col", "blockwise", "ref"),
    "attention": ("pallas", "blockwise", "ref"),
    "wkv6": ("pallas", "blockwise", "ref"),
}


def resolve_impl(op: str, impl: str = "auto",
                 interpret: bool | None = None) -> tuple[str, bool]:
    """Resolve (impl, interpret) for one op.  The single precedence order:

    1. an explicit ``impl`` (validated against the op's implementations)
       beats ``"auto"``, which picks "pallas" on TPU and "blockwise"
       elsewhere;
    2. an explicit ``interpret`` bool beats the default ``None``, which
       means "interpret when not on TPU" (Pallas kernels stay runnable on
       CPU CI).  The returned bool only matters for Pallas impls.
    """
    choices = _OP_IMPLS[op]
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "blockwise"
    if impl not in choices:
        raise ValueError(f"unknown {op} impl {impl!r}; expected "
                         f"{'|'.join(choices)}|auto")
    if interpret is None:
        interpret = not _on_tpu()
    return impl, interpret


# ---------------------------------------------------------------------------
# per-op kernel configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    """Tiling/math spec for `attention`.  None block sizes are filled from
    the autotune table (key: `autotune.attention_key`) or heuristics."""
    block_q: int | None = None       # pallas q tile (folded rep·Tq rows)
    block_k: int | None = None       # pallas kv tile / blockwise scan chunk
    acc_dtype: Any = jnp.float32     # blockwise score/accum math dtype
    gqa_broadcast: bool = False      # blockwise: einsum-broadcast GQA


@dataclasses.dataclass(frozen=True)
class ConvConfig:
    """Tiling spec for `conv2d`'s fused kernel; None fields let
    `log_conv2d_fused_pallas` clamp to the layer geometry.

    ``lane_pack`` controls the grouped-conv lane-packed layout (see
    `log_conv2d.lane_pack_geometry`): ``None`` auto-packs narrow groups
    into shared 128-lane blocks, ``1`` forces the padded per-group path,
    ``n ≥ 2`` packs up to ``n`` groups per block.  Precedence: an
    explicit value here beats a `QuantizedTensor`'s baked-in
    ``"lane_packed"`` layout (which is unpacked if they disagree), which
    beats the autotune table, which beats the auto heuristic."""
    block_cin: int | None = None
    block_cout: int | None = None
    rows_per_tile: int | None = None
    batch_per_tile: int | None = None
    lane_pack: int | None = None


@dataclasses.dataclass(frozen=True)
class WkvConfig:
    """Chunking spec for `wkv6` (chunk length bounds the exp dynamic
    range — see `kernels/wkv6.py`)."""
    chunk: int = 64


def _conv_config_dict(config) -> dict | None:
    if config is None:
        return None
    if isinstance(config, ConvConfig):
        return {k: v for k, v in dataclasses.asdict(config).items()
                if v is not None}
    return dict(config)


_CONV_CONFIG_FIELDS = tuple(f.name for f in dataclasses.fields(ConvConfig))

_WARNED_ONCE: set[str] = set()  # one-shot UserWarning dedupe, per process


def _warn_once(msg: str) -> None:
    if msg not in _WARNED_ONCE:
        _WARNED_ONCE.add(msg)
        warnings.warn(msg, UserWarning, stacklevel=3)


def _itemsize(x) -> int:
    try:
        return jnp.dtype(x.dtype).itemsize
    except TypeError:  # pragma: no cover - non-array convenience inputs
        return 4


def _profile_backend(interp: bool) -> str:
    return "interpret" if interp else jax.default_backend()


# ---------------------------------------------------------------------------
# log_matmul
# ---------------------------------------------------------------------------


def log_matmul(x, qt: QuantizedTensor, *, impl: str = "auto",
               interpret: bool | None = None):
    """x: [..., K] @ dequant(qt [K, N]) → [..., N]."""
    impl, interp = resolve_impl("log_matmul", impl, interpret)
    lead = x.shape[:-1]
    K = x.shape[-1]
    x2 = x.reshape(-1, K)
    scale = jnp.broadcast_to(jnp.asarray(qt.scale, jnp.float32),
                             (1, qt.packed.shape[-1]))
    if impl == "pallas":
        call = lambda: log_matmul_pallas(x2, qt.packed, scale, qt.cfg,
                                         interpret=interp, out_dtype=x.dtype)
    else:
        # blockwise == ref for a matmul: XLA fuses decode into the dot's
        # operand; weight bytes moved stay int8.
        call = lambda: _ref.ref_log_matmul(x2, qt.packed, scale, qt.cfg,
                                           out_dtype=x.dtype)
    if _kprof.PROFILER.enabled():
        M, N = x2.shape[0], qt.packed.shape[-1]
        it = _itemsize(x)
        act, w, outb = M * K * it, K * N, M * N * it  # codes move as int8
        traffic = {"act": act, "w": w, "out": outb,
                   "total": act + w + outb}
        key = f"log_matmul|{_profile_backend(interp)}|m{M}|k{K}|n{N}"
        out = _kprof.dispatch("log_matmul", impl, key, traffic, call,
                              traced=_kprof.is_traced(x))
    else:
        out = call()
    return out.reshape(*lead, -1)


# ---------------------------------------------------------------------------
# conv2d — the unified log-domain conv dispatch layer
# ---------------------------------------------------------------------------


def _hashable_padding(padding):
    if isinstance(padding, (list, tuple)):
        return tuple(tuple(p) if isinstance(p, (list, tuple)) else p
                     for p in padding)
    return padding


def conv2d(x, qt, *, stride: int = 1, padding="SAME", groups: int = 1,
           impl: str = "auto", interpret: bool | None = None,
           out_dtype=None, qcfg: LogQuantConfig | None = None,
           config: ConvConfig | dict | None = None, autotune: bool = False):
    """x: [B, H, W, Cin] ⊛ dequant(qt [K, K, Cin//groups, Cout]) → NHWC out.

    The single entry point of the three-tier conv stack (see
    `kernels/log_conv2d.py`): ``impl="pallas"`` is the fused
    implicit-im2col kernel (block sizes from the autotuner's on-disk table
    when present, heuristics otherwise; ``config=`` — a `ConvConfig` or
    plain dict — overrides, ``autotune=True`` measures candidates for
    this shape first and persists the winner), ``"pallas_im2col"`` the
    explicit-im2col fallback on `log_matmul_pallas`, ``"blockwise"`` the
    jnp fallback, ``"ref"`` the full-materialisation oracle; `auto` means
    pallas on TPU and blockwise elsewhere.  `qt` is a `QuantizedTensor`
    of packed log codes (per-output-channel scales supported; the
    serving-time ``layout="conv_taps"`` pre-reshape is accepted); a plain
    float array is packed on the fly as a convenience (inference only —
    quantization is not differentiable).  Supports stride,
    SAME/VALID/explicit padding, and grouped/depthwise convs
    (``groups=Cin``).
    """
    if not isinstance(qt, QuantizedTensor):
        qt = quantize_tensor(jnp.asarray(qt), qcfg or LogQuantConfig())
    packed = qt.packed
    layout = getattr(qt, "layout", None)
    lane_meta = None
    if layout == "conv_taps":
        packed = packed.reshape(qt.shape)  # [taps, cin_g, Cout] → 4-D HWIO
    elif layout == "lane_packed":
        lane_meta = tuple(qt.layout_meta)  # (g_b, cin_lane, groups)
    assert packed.ndim == 4, f"conv weights must be [K,K,Cin_g,Cout], " \
        f"got {packed.shape}"
    impl, interp = resolve_impl("conv2d", impl, interpret)
    padding = _hashable_padding(padding)
    config = _conv_config_dict(config)
    kw = dict(stride=stride, padding=padding, groups=groups,
              out_dtype=out_dtype)
    B, H, W, C = x.shape
    hwio = tuple(qt.shape) if lane_meta is not None else packed.shape
    K, Cout = hwio[0], hwio[-1]
    shape_kw = dict(stride=stride, padding=padding, groups=groups)
    prepacked = False
    if lane_meta is not None:
        # a baked "lane_packed" layout rides straight onto the fused
        # kernel when it matches this call; any disagreement (different
        # groups, an explicit conflicting lane_pack, a non-fused impl, or
        # an autotune sweep) falls back to unpacking the compact codes to
        # HWIO — always correct, just without the pre-arranged layout.
        g_b, cin_lane, meta_groups = lane_meta
        want = (config or {}).get("lane_pack")
        usable = (impl == "pallas" and meta_groups == groups
                  and want in (None, g_b) and not autotune)
        if usable:
            prepacked = True
        else:
            if (autotune and impl == "pallas" and meta_groups == groups
                    and want in (None, g_b)):
                # the sweep still runs, but silently discarding the baked
                # layout surprises callers expecting the prepacked path
                _warn_once(
                    "ops.conv2d: autotune=True unpacked the baked "
                    "'lane_packed' weight layout for the tuning sweep; the "
                    "tuned entry applies to the unpacked HWIO path")
            packed = lane_unpack_codes(packed, hwio, meta_groups, g_b,
                                       cin_lane)
    if impl == "pallas":
        explicit = config or {}
        if autotune and explicit:
            _warn_once(
                f"ops.conv2d: autotune=True is a no-op because config= pins "
                f"{sorted(explicit)}; drop the explicit config to run the "
                f"tuning sweep for this shape")
        if autotune and not explicit:
            config = _autotune.autotune_conv2d(
                x, packed, qt.scale, qt.cfg, interpret=interp, **shape_kw)
        elif any(f not in explicit for f in _CONV_CONFIG_FIELDS):
            # the documented contract: fields left unset are filled
            # per-field from the layered autotune table (or heuristics) —
            # a partial config (e.g. only lane_pack) keeps the tuned tiling
            key = _autotune.conv_key(
                B, H, W, C, K, Cout, cfg=qt.cfg, **shape_kw,
                backend=("interpret" if interp else None))
            tuned = _autotune.lookup(key) or _autotune.default_config(
                B, H, W, C, K, Cout, **shape_kw)
            config = {**tuned, **explicit}
        else:
            config = explicit
        if prepacked:  # the baked layout forces its own lane_pack factor
            config = dict(config, lane_pack=lane_meta[0])
        call = lambda: log_conv2d_fused_pallas(x, packed, qt.scale, qt.cfg,
                                               interpret=interp,
                                               prepacked=prepacked, **kw,
                                               **config)
    elif impl == "pallas_im2col":
        call = lambda: log_conv2d_pallas(x, packed, qt.scale, qt.cfg,
                                         interpret=interp, **kw)
    elif impl == "ref":
        call = lambda: log_conv2d_ref(x, packed, qt.scale, qt.cfg, **kw)
    else:
        call = lambda: log_conv2d_blockwise(x, packed, qt.scale, qt.cfg,
                                            **kw)
    if not _kprof.PROFILER.enabled():
        return call()
    # the oracle materialises full-precision patches: model it as "fp32"
    traffic_impl = {"ref": "fp32"}.get(impl, impl)
    traffic = conv_traffic_bytes(
        traffic_impl, B, H, W, C, K, Cout, **shape_kw,
        config=(config if impl == "pallas" else None))
    key = _autotune.conv_key(B, H, W, C, K, Cout, cfg=qt.cfg, **shape_kw,
                             backend=_profile_backend(interp))
    return _kprof.dispatch("conv2d", impl, key, traffic, call,
                           traced=_kprof.is_traced(x, packed))


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def _blockwise_attention(q, k, v, *, causal, window, scale, q_offset,
                         k_offset=0, block_k: int = 1024,
                         acc_dtype=jnp.float32, gqa_broadcast: bool = False):
    """Online-softmax over kv blocks with lax.scan — O(Tq·bk) live memory.

    q: [B, Tq, H, D]; k, v: [B, Tk, Hkv, D].

    §Perf knobs: `acc_dtype` runs the score/accumulator math in bf16
    (running max/sum stay f32 for stability); `gqa_broadcast` reshapes q to
    [B,Tq,Hkv,rep,D] and contracts against unexpanded K/V instead of
    materialising rep× repeated K/V blocks."""
    B, Tq, H, D = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    f32 = jnp.float32
    cdt = acc_dtype

    pk = (-Tk) % block_k
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nkv = (Tk + pk) // block_k
    # [nkv, B, bk, Hkv, D]
    kc = kp.reshape(B, nkv, block_k, Hkv, D).transpose(1, 0, 2, 3, 4)
    vc = vp.reshape(B, nkv, block_k, Hkv, D).transpose(1, 0, 2, 3, 4)

    use_bcast = gqa_broadcast and rep > 1
    qf = (q.astype(cdt) * jnp.asarray(scale, cdt))
    if use_bcast:
        qf = qf.reshape(B, Tq, Hkv, rep, D)
    qpos = jnp.arange(Tq) + q_offset

    def step(carry, inp):
        m, l, acc = carry                 # [B,H,Tq,1], [B,H,Tq,1], [B,H,Tq,D]
        kb, vb, kv_idx = inp
        if use_bcast:
            # s: [B, Hkv, rep, Tq, bk] without expanding K
            s = jnp.einsum("bqhrd,bkhd->bhrqk", qf, kb.astype(cdt))
            s = s.reshape(B, H, Tq, block_k)
        else:
            if rep > 1:
                kb = jnp.repeat(kb, rep, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", qf, kb.astype(cdt))
        s = s.astype(f32)
        kpos = kv_idx * block_k + jnp.arange(block_k) + k_offset
        mask = (kpos[None, :] < Tk + k_offset) & (kpos[None, :] >= 0)
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            mask &= (qpos[:, None] - kpos[None, :]) < window
        s = jnp.where(mask[None, None], s, -1e30)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, m_cur)
        p = jnp.where(mask[None, None], jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m - m_new)
        l = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
        if use_bcast:
            pv = jnp.einsum("bhrqk,bkhd->bqhrd",
                            p.reshape(B, Hkv, rep, Tq, block_k).astype(cdt),
                            vb.astype(cdt))
            pv = pv.reshape(B, Tq, H, D).transpose(0, 2, 1, 3)
        else:
            vb_ = jnp.repeat(vb, rep, axis=2) if rep > 1 else vb
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(cdt),
                            vb_.astype(cdt))
        acc = alpha * acc + pv.astype(f32)
        return (m_new, l, acc), None

    init = (jnp.full((B, H, Tq, 1), -1e30, f32),
            jnp.zeros((B, H, Tq, 1), f32),
            jnp.zeros((B, H, Tq, D), f32))
    (m, l, acc), _ = jax.lax.scan(step, init,
                                  (kc, vc, jnp.arange(nkv)))
    out = acc / jnp.where(l > 0, l, 1.0)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


_UNSET = object()  # legacy-kwarg sentinel: distinguishes "not passed"

_LEGACY_ATTN_FIELDS = ("block_k", "acc_dtype", "gqa_broadcast")


def _translate_legacy_attn_kwargs(config, legacy: dict):
    """One-release deprecation shim: `block_k=`/`acc_dtype=`/
    `gqa_broadcast=` become `AttentionConfig` fields."""
    passed = {n: v for n, v in legacy.items() if v is not _UNSET}
    if not passed:
        return config or AttentionConfig()
    warnings.warn(
        f"ops.attention({', '.join(sorted(passed))}=…) is deprecated; pass "
        f"config=AttentionConfig(...) instead (legacy kwargs are removed "
        f"next release)", DeprecationWarning, stacklevel=3)
    if config is not None:
        raise ValueError("pass either config=AttentionConfig(...) or the "
                         f"legacy kwargs {sorted(passed)}, not both")
    return AttentionConfig(**passed)


def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              scale=None, q_offset=0, k_offset=0, impl: str = "auto",
              config: AttentionConfig | None = None, autotune: bool = False,
              interpret: bool | None = None, block_k=_UNSET,
              acc_dtype=_UNSET, gqa_broadcast=_UNSET):
    """GQA/MQA attention.  q: [B, Tq, H, D]; k, v: [B, Tk, Hkv, D] with H a
    multiple of Hkv.

    The Pallas impl is GQA-native: an explicit kv-head grid dimension
    loads each kv head's K/V tiles into VMEM once and broadcasts them
    across its H/Hkv query heads, so K/V HBM traffic scales with Hkv (no
    `jnp.repeat` anywhere).  `q_offset`/`k_offset` may be traced scalars
    (decode at a dynamic cache index) on every impl — the kernel takes
    them as scalar-prefetch operands.

    Block sizes come from ``config=AttentionConfig(...)``; fields left at
    None are filled from the autotune table (``autotune=True`` measures
    candidates for this shape first) or heuristics.  ``block_k=`` /
    ``acc_dtype=`` / ``gqa_broadcast=`` remain accepted as deprecated
    aliases for one release.
    """
    config = _translate_legacy_attn_kwargs(
        config, dict(block_k=block_k, acc_dtype=acc_dtype,
                     gqa_broadcast=gqa_broadcast))
    B, Tq, H, D = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    if k.shape != v.shape or k.shape[0] != B or k.shape[3] != D:
        raise ValueError(f"inconsistent attention operands: q {q.shape}, "
                         f"k {k.shape}, v {v.shape}")
    if Hkv == 0 or H % Hkv != 0:
        raise ValueError(
            f"GQA requires query heads divisible by kv heads; got H={H} "
            f"query heads vs Hkv={Hkv} kv heads (q {q.shape}, k {k.shape})")
    impl, interp = resolve_impl("attention", impl, interpret)
    traffic_kw = {}
    if impl == "ref":
        call = lambda: _ref.ref_attention(q, k, v, causal=causal,
                                          window=window, scale=scale,
                                          q_offset=q_offset,
                                          k_offset=k_offset)
    elif impl == "blockwise":
        call = lambda: _blockwise_attention(
            q, k, v, causal=causal, window=window, scale=scale,
            q_offset=q_offset, k_offset=k_offset,
            block_k=config.block_k or 1024, acc_dtype=config.acc_dtype,
            gqa_broadcast=config.gqa_broadcast)
    else:
        # pallas (GQA-native; dynamic offsets ride the scalar-prefetch
        # operand)
        bq, bk = config.block_q, config.block_k
        if autotune and bq is not None and bk is not None:
            _warn_once(
                "ops.attention: autotune=True is a no-op because config= "
                "pins both block_q and block_k; leave one unset to run the "
                "tuning sweep for this shape")
        if bq is None or bk is None:
            if autotune:
                tuned = _autotune.autotune_attention(
                    q, k, v, causal=causal, window=window, scale=scale,
                    interpret=interp)
            else:
                key = _autotune.attention_key(
                    B, Tq, Tk, H, Hkv, D, causal=causal, window=window,
                    backend=("interpret" if interp else None))
                tuned = _autotune.lookup(key) or \
                    _autotune.default_attention_config(B, Tq, Tk, H, Hkv, D)
            bq = bq if bq is not None else tuned["block_q"]
            bk = bk if bk is not None else tuned["block_k"]
        traffic_kw = dict(block_q=bq, block_k=bk)
        call = lambda: flash_attention_pallas(
            q, k, v, causal=causal, window=window, scale=scale,
            q_offset=q_offset, k_offset=k_offset, block_q=bq, block_k=bk,
            interpret=interp)
    if not _kprof.PROFILER.enabled():
        return call()
    traffic = attention_traffic_bytes(impl, B, Tq, Tk, H, Hkv, D,
                                      itemsize=_itemsize(q), **traffic_kw)
    key = _autotune.attention_key(B, Tq, Tk, H, Hkv, D, causal=causal,
                                  window=window,
                                  backend=_profile_backend(interp))
    return _kprof.dispatch(
        "attention", impl, key, traffic, call,
        traced=_kprof.is_traced(q, k, v, q_offset, k_offset))


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------


def wkv6(r, k, v, logw, u, state=None, *, impl: str = "auto",
         config: WkvConfig | None = None, chunk: int | None = None,
         interpret: bool | None = None):
    """RWKV6 WKV.  ``config=WkvConfig(chunk=…)`` is the spec'd surface;
    ``chunk=`` stays as a positional-friendly alias."""
    impl, interp = resolve_impl("wkv6", impl, interpret)
    chunk = chunk if chunk is not None else (config or WkvConfig()).chunk
    if impl == "ref":
        call = lambda: _ref.ref_wkv6(r, k, v, logw, u, state)
    elif impl == "blockwise":
        call = lambda: wkv6_chunked_jnp(r, k, v, logw, u, state, chunk=chunk)
    else:
        call = lambda: wkv6_pallas(r, k, v, logw, u, state, chunk=chunk,
                                   interpret=interp)
    if not _kprof.PROFILER.enabled():
        return call()
    B, T, H, K = r.shape
    V = v.shape[-1]
    it = _itemsize(r)
    rkw = 3 * B * T * H * K * it            # r, k and per-step decay logw
    vb = 2 * B * T * H * V * it             # v in, wkv out
    st = 2 * B * H * K * V * 4              # state read + write (f32)
    traffic = {"rkw": rkw, "v": vb, "state": st, "u": H * K * it,
               "total": rkw + vb + st + H * K * it}
    key = (f"wkv6|{_profile_backend(interp)}|b{B}|t{T}|h{H}|k{K}|v{V}"
           f"|c{chunk}")
    return _kprof.dispatch("wkv6", impl, key, traffic, call,
                           traced=_kprof.is_traced(r, k, v, logw, u, state))
