"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the `tests/test_kernels_*.py` allclose sweeps
(kernels run with interpret=True on CPU) and double as readable specs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.logquant import LogQuantConfig, log_dequantize

# ---------------------------------------------------------------------------
# log_matmul: x @ dequant(packed codes)  — the NeuroMAX decode-at-the-PE path
# ---------------------------------------------------------------------------


def ref_log_matmul(x, packed, scale, cfg: LogQuantConfig = LogQuantConfig(),
                   out_dtype=None):
    """x: [M, K] float; packed: [K, N] int8 log codes; scale: [1, N] or scalar."""
    w = log_dequantize(packed, scale, cfg, dtype=jnp.float32)
    out = jnp.dot(x.astype(jnp.float32), w, precision=jax.lax.Precision.HIGHEST)
    return out.astype(out_dtype or x.dtype)


# ---------------------------------------------------------------------------
# attention (causal / sliding-window), full-softmax reference
# ---------------------------------------------------------------------------


def ref_attention(q, k, v, *, causal=True, window=None, scale=None,
                  q_offset=0, k_offset=0):
    """q: [B, Tq, H, D]; k, v: [B, Tk, Hkv, D] (GQA: H multiple of Hkv).

    window: sliding-window size (keys with q_pos - k_pos >= window masked).
    q_offset: absolute position of q[0] (for decode: q_offset = Tk - Tq).
    k_offset: absolute position of k[0] (ring-buffer caches; keys with
    absolute position < 0 are masked as never-written slots).
    """
    B, Tq, H, D = q.shape
    Hkv = k.shape[2]
    rep = H // Hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(D).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qpos = jnp.arange(Tq)[:, None] + q_offset
    kpos = jnp.arange(k.shape[1])[None, :] + k_offset
    mask = kpos >= 0
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# RWKV6 (Finch) WKV with data-dependent decay — sequential reference
# ---------------------------------------------------------------------------


def ref_wkv6(r, k, v, logw, u, state=None):
    """Sequential WKV6 recurrence (the spec).

    r, k: [B, T, H, K]; v: [B, T, H, V]; logw: [B, T, H, K] (log decay ≤ 0,
    data-dependent — 'Finch'); u: [H, K] bonus for the current token.

        o_t = r_t · (S_{t-1} + diag(u) k_t v_t^T)
        S_t = diag(w_t) S_{t-1} + k_t v_t^T
    Returns (o: [B, T, H, V], S_T: [B, H, K, V]).
    """
    B, T, H, K = r.shape
    V = v.shape[-1]
    f32 = jnp.float32
    r, k, v, logw = (a.astype(f32) for a in (r, k, v, logw))
    u = u.astype(f32)
    if state is None:
        state = jnp.zeros((B, H, K, V), f32)

    def step(S, inp):
        rt, kt, vt, lwt = inp  # [B,H,K],[B,H,K],[B,H,V],[B,H,K]
        kv = kt[..., :, None] * vt[..., None, :]             # [B,H,K,V]
        o = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
        S = jnp.exp(lwt)[..., None] * S + kv
        return S, o

    xs = (jnp.moveaxis(r, 1, 0), jnp.moveaxis(k, 1, 0),
          jnp.moveaxis(v, 1, 0), jnp.moveaxis(logw, 1, 0))
    S, o = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(o, 0, 1), S


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma) — sequential reference
# ---------------------------------------------------------------------------


def ref_rglru(x, gate_a, state=None, c: float = 8.0):
    """h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ x_t,  a_t = exp(c·log σ… )

    x: [B, T, D] (already input-gated); gate_a: [B, T, D] the recurrence gate
    *pre-activation combined with Λ*: a_t = exp(-c · softplus(Λ) · σ(g)) is
    computed by the caller; here gate_a IS log(a_t) ≤ 0 for testability.
    """
    f32 = jnp.float32
    x, gate_a = x.astype(f32), gate_a.astype(f32)
    B, T, D = x.shape
    if state is None:
        state = jnp.zeros((B, D), f32)
    a = jnp.exp(gate_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0))

    def step(h, inp):
        at, xt, mt = inp
        h = at * h + mt * xt
        return h, h

    xs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(x, 1, 0),
          jnp.moveaxis(mult, 1, 0))
    hT, hs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(hs, 0, 1), hT
