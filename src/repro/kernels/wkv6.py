"""Pallas TPU kernel: chunked RWKV6 (Finch) WKV with data-dependent decay.

The WKV recurrence  S_t = diag(w_t) S_{t-1} + k_t v_tᵀ,
                    o_t = r_t (S_{t-1} + diag(u) k_t v_tᵀ)
is sequential per token; the chunked form turns it into MXU matmuls.
With P̃_t = Σ_{s≤t} log w_s (per k-channel cumulative log decay):

  intra-chunk:  o = (q̃ K̃ᵀ ⊙ strict-causal) V + diag(r·(u⊙k)) V + q̃ S₀
                q̃_t = r_t ⊙ exp(P̃_{t-1}),   K̃_s = k_s ⊙ exp(−P̃_s)
  state update: S_L = diag(exp(P̃_L)) S₀ + K̂ᵀ V,  K̂_t = k_t ⊙ exp(P̃_L − P̃_t)

The exp factorisation is exact; within a chunk P̃ ∈ [Σlog w, 0] so both
factors are bounded by exp(|Σ log w|) — chunk length bounds the dynamic
range (default 64, safe in fp32 for log w ≥ −40/chunk in practice).

Grid: (B·H, chunks) with chunks innermost/sequential; the running state
S [K, V] lives in VMEM scratch and is carried across chunk steps — the same
"psums never leave the core" property as the paper's adder nets.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import TPUCompilerParams


def _chunk_math(r, k, v, logw, u, S0):
    """One chunk of the closed form above.  All inputs fp32.

    r, k, logw: [L, K]; v: [L, V]; u: [K]; S0: [K, V] → (o [L, V], S_L)."""
    L = r.shape[0]
    p = jnp.cumsum(logw, axis=0)                       # P̃_t, [L, K]
    p_prev = p - logw                                  # P̃_{t-1}
    q_t = r * jnp.exp(p_prev)
    k_t = k * jnp.exp(-p)
    a = jax.lax.dot_general(q_t, k_t, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [L, L]
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    a = jnp.where(ii > jj, a, 0.0)                     # strict causal
    o = jnp.dot(a, v, preferred_element_type=jnp.float32)
    o += jnp.sum(r * (u[None] * k), axis=1, keepdims=True) * v
    o += jnp.dot(q_t, S0, preferred_element_type=jnp.float32)
    pL = p[-1]
    k_hat = k * jnp.exp(pL[None] - p)
    S = jnp.exp(pL)[:, None] * S0 + jax.lax.dot_general(
        k_hat, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    return o, S


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref,
                S_ref, *, chunk):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        S_ref[...] = s0_ref[0].astype(jnp.float32)

    o, S = _chunk_math(r_ref[0].astype(jnp.float32),
                       k_ref[0].astype(jnp.float32),
                       v_ref[0].astype(jnp.float32),
                       w_ref[0].astype(jnp.float32),
                       u_ref[0].astype(jnp.float32),
                       S_ref[...])
    o_ref[0] = o.astype(o_ref.dtype)
    S_ref[...] = S

    @pl.when(c == pl.num_programs(1) - 1)
    def _flush():
        sT_ref[0] = S_ref[...].astype(sT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(r, k, v, logw, u, state=None, *, chunk=64, interpret=False):
    """r, k: [B, T, H, K]; v: [B, T, H, V]; logw: [B, T, H, K]; u: [H, K].

    Returns (o: [B, T, H, V], S_T: [B, H, K, V]).  T padded to chunk
    multiples with log w = 0, k = 0 (identity updates)."""
    B, T, H, K = r.shape
    V = v.shape[-1]
    if state is None:
        state = jnp.zeros((B, H, K, V), jnp.float32)

    pt = (-T) % chunk
    pad4 = ((0, 0), (0, pt), (0, 0), (0, 0))
    rp, kp, vp, wp = (jnp.pad(a, pad4) for a in (r, k, v, logw))
    Tp = T + pt

    def bh(a):  # [B, T, H, X] → [B·H, T, X]
        return a.transpose(0, 2, 1, 3).reshape(B * H, Tp, -1)

    rp, kp, vp, wp = bh(rp), bh(kp), bh(vp), bh(wp)
    up = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, K)
    sp = state.reshape(B * H, K, V)

    o, sT = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk),
        grid=(B * H, Tp // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, V), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, K), lambda b, c: (b, 0)),
            pl.BlockSpec((1, K, V), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, V), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, K, V), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, Tp, V), r.dtype),
            jax.ShapeDtypeStruct((B * H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
        compiler_params=TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(rp, kp, vp, wp, up, sp)

    o = o.reshape(B, H, Tp, V).transpose(0, 2, 1, 3)[:, :T]
    return o, sT.reshape(B, H, K, V)


def wkv6_chunked_jnp(r, k, v, logw, u, state=None, *, chunk=64):
    """Pure-jnp chunked fallback (same math; used for CPU lowering paths)."""
    B, T, H, K = r.shape
    V = v.shape[-1]
    f32 = jnp.float32
    if state is None:
        state = jnp.zeros((B, H, K, V), f32)
    pt = (-T) % chunk
    pad4 = ((0, 0), (0, pt), (0, 0), (0, 0))
    rp, kp, vp, wp = (jnp.pad(a.astype(f32), pad4) for a in (r, k, v, logw))
    Tp = T + pt
    nC = Tp // chunk

    def to_chunks(a):  # [B, Tp, H, X] → [nC, B, H, chunk, X]
        X = a.shape[-1]
        return a.reshape(B, nC, chunk, H, X).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, wc = to_chunks(rp), to_chunks(kp), to_chunks(vp), to_chunks(wp)
    uf = u.astype(f32)

    vmapped = jax.vmap(jax.vmap(_chunk_math, in_axes=(0, 0, 0, 0, 0, 0)),
                       in_axes=(0, 0, 0, 0, None, 0))

    def step(S, inp):
        rci, kci, vci, wci = inp
        o, S = vmapped(rci, kci, vci, wci, uf, S)
        return S, o

    S, o = jax.lax.scan(step, state.astype(f32), (rc, kc, vc, wc))
    o = o.transpose(1, 0, 3, 2, 4).reshape(B, Tp, H, V)[:, :T]
    return o.astype(r.dtype), S
