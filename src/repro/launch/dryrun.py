import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, extract memory/cost/collective numbers for §Roofline.

MUST be run as its own process (the two lines above must execute before any
jax device initialisation — never import this module from tests).

Per cell this performs up to four compiles:
  prod-single   production program, 16×16 mesh → memory_analysis,
                collective schedule, compile-success
  acct-u1/u2    accounting program (attn_block_k=S, xent_chunk=T — both
                provably cost-identical for our blockwise kernels — layer
                scan unroll 1 and 2) → unroll-diff-corrected cost:
                    true = A + (n_rep−1)·(B−A)
                because the XLA cost model counts while bodies once.
  prod-multi    production program on the (2,16,16) 512-chip mesh →
                compile-success + memory (proves the "pod" axis shards)

Known, documented approximation: inner chunked scans of rwkv6 (wkv chunk
loop) remain while-loops in the accounting program; their bodies are <1–2 %
of layer cost (projections dominate), so the undercount is negligible —
see DESIGN.md §Known deviations.

Results: one JSON per (arch, shape, mesh) under --out (skip-if-exists →
restartable).  EXPERIMENTS.md §Dry-run / §Roofline are generated from these
records by analysis/roofline.py.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from ..analysis.roofline import collective_bytes, model_flops_for
from ..configs.base import SHAPES, cell_is_runnable
from ..configs.registry import ARCH_NAMES, get_config
from .mesh import make_production_mesh
from .steps import build_step


def _plain_cost(compiled) -> dict:
    ca = compiled.cost_analysis()
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}


def _memory(compiled) -> dict:
    ma = compiled.memory_analysis()
    return {"argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes)}


def _compile(cfg, shape_name, mesh, *, donate=True):
    kind, (fn, abs_args, shard_args) = build_step(cfg, shape_name, mesh)
    donate_argnums = ()
    if donate:
        donate_argnums = (0,) if kind == "train" else \
            ((1,) if kind == "decode" else ())
    with mesh:
        lowered = jax.jit(fn, in_shardings=shard_args,
                          donate_argnums=donate_argnums).lower(*abs_args)
        compiled = lowered.compile()
    return kind, compiled


def _main_seg_reps(cfg) -> int:
    reps = [r for _, r in cfg.segments if r > 1]
    assert len(reps) <= 1, f"{cfg.name}: >1 multi-rep segment {cfg.segments}"
    return reps[0] if reps else 1


def _opt_cfg(cfg, shape_name):
    """The §Perf winning combination per step kind ('--variant opt')."""
    import jax.numpy as jnp
    step = SHAPES[shape_name]["step"]
    if step in ("train", "prefill"):
        # per-arch measured winners (autotuned layout table — both
        # candidate layouts were measured for every regressing cell; see
        # EXPERIMENTS.md §Perf): llama's 53k d_ff makes seq-FSDP gather
        # 13 GiB of FFN weights per layer, so it stays on the baseline
        # Megatron layout; musicgen prefill likewise.
        if (cfg.name, step) in {("llama3-405b", "train"),
                                ("llama3-405b", "prefill"),
                                ("musicgen-large", "prefill")}:
            # pure baseline: even gqa_broadcast regresses here — the
            # [B,T,Hkv,rep,D] reshape splits the head axis and breaks the
            # 128-head model-axis sharding (measured 0.72×).
            return cfg
        return dataclasses.replace(
            cfg, attn_shard="seq", residual_shard="seq",
            attn_acc_dtype=jnp.bfloat16, gqa_broadcast=True)
    # decode: broadcast-GQA only for the measured sweep.  Packed logq6
    # weights (the paper's serving form) win on TPU where log_matmul
    # decodes in VMEM, but XLA-CPU materialises the f32 dequant and
    # inflates the measured memory term — see EXPERIMENTS.md §Perf cell 2.
    return dataclasses.replace(cfg, gqa_broadcast=True)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str, verbose: bool = True,
             variant: str = "baseline") -> dict:
    mesh_name = "multi" if multi_pod else "single"
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    if variant == "opt":
        cfg = _opt_cfg(cfg, shape_name)
    sh = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "chips": 512 if multi_pod else 256,
           "model_flops": model_flops_for(cfg, sh),
           "params": cfg.param_count(),
           "active_params": cfg.active_param_count()}

    if not cell_is_runnable(arch, shape_name):
        rec["skipped"] = "full-attention arch at 500k context"
        _save(path, rec)
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)

        # ---- production compile: memory + collective schedule ----------
        kind, compiled = _compile(cfg, shape_name, mesh)
        rec["step_kind"] = kind
        rec["memory"] = _memory(compiled)
        coll_prod = collective_bytes(compiled.as_text())
        rec["collectives_prod_once"] = coll_prod
        rec["cost_prod_once"] = _plain_cost(compiled)
        t_prod = time.time() - t0
        del compiled

        if multi_pod:
            # multi-pod pass = compile success + memory; roofline table is
            # single-pod only (assignment).
            rec["timings"] = {"prod_compile_s": t_prod}
            _save(path, rec)
            return rec

        # ---- accounting compiles: unroll-diff cost correction -----------
        S = sh["seq_len"]
        n_rep = _main_seg_reps(cfg)
        acct = dataclasses.replace(cfg, attn_block_k=S, scan_unroll=1)
        _, cA = _compile(acct, shape_name, mesh, donate=False)
        costA, collA = _plain_cost(cA), collective_bytes(cA.as_text())
        del cA
        if n_rep > 1:
            acct2 = dataclasses.replace(acct, scan_unroll=2)
            _, cB = _compile(acct2, shape_name, mesh, donate=False)
            costB, collB = _plain_cost(cB), collective_bytes(cB.as_text())
            del cB
        else:
            costB, collB = costA, collA

        k = n_rep - 1
        rec["cost_true"] = {
            "flops": costA["flops"] + k * (costB["flops"] - costA["flops"]),
            "bytes": costA["bytes"] + k * (costB["bytes"] - costA["bytes"]),
            "collective_bytes":
                collA["total"] + k * (collB["total"] - collA["total"]),
        }
        rec["cost_acct_u1"] = {**costA, "collective_bytes": collA["total"],
                               "coll_by_type": collA["by_type"]}
        rec["cost_acct_u2"] = {**costB, "collective_bytes": collB["total"]}
        rec["n_rep_main_segment"] = n_rep
        rec["timings"] = {"prod_compile_s": t_prod,
                          "total_s": time.time() - t0}
    except Exception as e:  # record the failure — it is a bug to fix
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"  FAILED {arch}/{shape_name}/{mesh_name}: {rec['error']}")
    _save(path, rec)
    if verbose and "error" not in rec:
        extra = ""
        if "cost_true" in rec:
            extra = (f" flops/dev={rec['cost_true']['flops']:.3e}"
                     f" coll/dev={rec['cost_true']['collective_bytes']:.3e}")
        print(f"  ok {arch}/{shape_name}/{mesh_name}"
              f" mem={rec['memory']['temp_bytes']/2**30:.1f}GiB"
              f"{extra} ({time.time()-t0:.0f}s)")
    return rec


def _save(path, rec):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path + ".tmp", "w") as f:
        json.dump(rec, f, indent=1)
    os.replace(path + ".tmp", path)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=list(ARCH_NAMES))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch × shape) × {single, multi}")
    ap.add_argument("--single-only", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "opt"])
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_NAMES:
            for shape in SHAPES:
                cells.append((arch, shape, False))
                if not args.single_only:
                    cells.append((arch, shape, True))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape or --all required")
        cells.append((args.arch, args.shape, args.multi_pod))

    print(f"dry-run: {len(cells)} cells, devices={len(jax.devices())}, "
          f"variant={args.variant}")
    for arch, shape, mp in cells:
        run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                 variant=args.variant)
    # summary
    bad = []
    for arch, shape, mp in cells:
        p = os.path.join(args.out,
                         f"{arch}__{shape}__{'multi' if mp else 'single'}.json")
        with open(p) as f:
            if "error" in json.load(f):
                bad.append(p)
    print(f"done: {len(cells) - len(bad)}/{len(cells)} ok")
    for p in bad:
        print("  FAILED:", p)


if __name__ == "__main__":
    main()
