"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first jax init,
and smoke tests must keep seeing 1 device.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple, axes: tuple):
    """Arbitrary mesh (elastic restarts onto shrunken worlds)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh():
    """Whatever this host actually has — for examples/ and smoke runs."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
