import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb harness: lower one (arch × shape) cell with a named
config variant, extract the three roofline terms (unroll-diff-corrected),
and append the iteration to results/perf/<arch>__<shape>.jsonl.

    PYTHONPATH=src python -m repro.launch.perf --arch gemma3-1b \
        --shape train_4k --variant heads_tp

Variants are config transforms registered in VARIANTS — the baseline is the
paper-faithful config ("baseline"); each hillclimb hypothesis is one named
variant so every row in EXPERIMENTS.md §Perf is reproducible.
"""

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from ..analysis.roofline import (Roofline, collective_bytes,
                                 model_flops_for)
from ..configs.base import SHAPES
from ..configs.registry import ARCH_NAMES, get_config
from .dryrun import _compile, _main_seg_reps, _memory, _plain_cost
from .mesh import make_production_mesh

# ----------------------------------------------------------------- variants

def _v(**kw):
    return lambda cfg: dataclasses.replace(cfg, **kw)


def _chain(*fns):
    def apply(cfg):
        for f in fns:
            cfg = f(cfg)
        return cfg
    return apply


VARIANTS = {
    "baseline": _v(),
    # H1: Megatron-style head sharding for q/k/v + attention out
    "heads_tp": _v(attn_shard="heads"),
    # H2: bf16 attention math (running stats stay f32)
    "attn_bf16": _v(attn_acc_dtype=jnp.bfloat16),
    # H3: GQA via broadcast einsum (no kv repeat)
    "gqa_bcast": _v(gqa_broadcast=True),
    # combinations
    "heads+bf16": _v(attn_shard="heads", attn_acc_dtype=jnp.bfloat16),
    "heads+bf16+bcast": _v(attn_shard="heads",
                           attn_acc_dtype=jnp.bfloat16, gqa_broadcast=True),
    # H4: paper technique on serving weights — logq6 fake-quant path marks
    # weight reads 6-bit in the kernel; modelled in the memory term
    "logq6": _v(quant="logq6"),
    "heads+bf16+logq6": _v(attn_shard="heads",
                           attn_acc_dtype=jnp.bfloat16, quant="logq6"),
    # H5: block size sweeps for the blockwise kernels
    "block2048": _v(attn_block_k=2048),
    "block4096": _v(attn_block_k=4096),
    # H6: no remat (memory for flops trade)
    "noremat": _v(remat=False),
    "heads+bf16+noremat": _v(attn_shard="heads",
                             attn_acc_dtype=jnp.bfloat16, remat=False),
    # H7: sequence parallelism (query/residual seq-sharded over model)
    "seq_tp": _v(attn_shard="seq"),
    "seq_tp+res": _v(attn_shard="seq", residual_shard="seq"),
    "seq_tp+res+bf16": _v(attn_shard="seq", residual_shard="seq",
                          attn_acc_dtype=jnp.bfloat16),
    "seq_tp+res+bf16+bcast": _v(attn_shard="seq", residual_shard="seq",
                                attn_acc_dtype=jnp.bfloat16,
                                gqa_broadcast=True),
    # H8: decode combos — head-whole layouts + no kv repeat + packed 6-bit
    # serving weights (the paper's storage format end to end)
    "heads+bcast": _v(attn_shard="heads", gqa_broadcast=True),
    "heads+bcast+logq6": _v(attn_shard="heads", gqa_broadcast=True,
                            quant="logq6"),
    "bcast+logq6": _v(gqa_broadcast=True, quant="logq6"),
    # H9: bf16 parameters — halves FSDP weight gathers AND grad reductions
    # (optimizer keeps f32 mu/nu as master statistics)
    "params_bf16": _v(param_dtype=jnp.bfloat16),
    "seq+all+params_bf16": _v(attn_shard="seq", residual_shard="seq",
                              attn_acc_dtype=jnp.bfloat16,
                              gqa_broadcast=True,
                              param_dtype=jnp.bfloat16),
    # H10: Megatron-SP — activations gathered at block input, weights stay
    # TP-sharded, residual reduce-scattered (wins when weights ≫ acts)
    "megatron_sp": _v(attn_shard="seq", residual_shard="seq",
                      sp_style="megatron", attn_acc_dtype=jnp.bfloat16,
                      gqa_broadcast=True),
    "megatron_sp+heads": _v(attn_shard="heads", residual_shard="seq",
                            sp_style="megatron",
                            attn_acc_dtype=jnp.bfloat16,
                            gqa_broadcast=True),
}


def run_variant(arch: str, shape_name: str, variant: str, *,
                out_dir: str = "results/perf", note: str = "") -> dict:
    cfg = VARIANTS[variant](get_config(arch))
    sh = SHAPES[shape_name]
    mesh = make_production_mesh()
    t0 = time.time()

    # production compile (memory + schedule)
    kind, compiled = _compile(cfg, shape_name, mesh)
    mem = _memory(compiled)
    del compiled

    # accounting compiles
    S = sh["seq_len"]
    n_rep = _main_seg_reps(cfg)
    acct = dataclasses.replace(cfg, attn_block_k=S, scan_unroll=1)
    _, cA = _compile(acct, shape_name, mesh, donate=False)
    costA, collA = _plain_cost(cA), collective_bytes(cA.as_text())
    del cA
    if n_rep > 1:
        _, cB = _compile(dataclasses.replace(acct, scan_unroll=2),
                         shape_name, mesh, donate=False)
        costB, collB = _plain_cost(cB), collective_bytes(cB.as_text())
        del cB
    else:
        costB, collB = costA, collA
    k = n_rep - 1
    true = {
        "flops": costA["flops"] + k * (costB["flops"] - costA["flops"]),
        "bytes": costA["bytes"] + k * (costB["bytes"] - costA["bytes"]),
        "collective_bytes":
            collA["total"] + k * (collB["total"] - collA["total"]),
    }
    coll_by_type = {t: collA["by_type"].get(t, 0)
                    + k * (collB["by_type"].get(t, 0)
                           - collA["by_type"].get(t, 0))
                    for t in set(collA["by_type"]) | set(collB["by_type"])}

    r = Roofline(arch=arch, shape=shape_name, mesh="single", chips=256,
                 flops_per_dev=true["flops"], bytes_per_dev=true["bytes"],
                 coll_bytes_per_dev=true["collective_bytes"],
                 model_flops=model_flops_for(cfg, sh),
                 memory_per_dev=mem["temp_bytes"] + mem["argument_bytes"])
    rec = {"arch": arch, "shape": shape_name, "variant": variant,
           "note": note, "cost_true": true, "coll_by_type": coll_by_type,
           "memory": mem, "row": r.row(),
           "compile_s": round(time.time() - t0, 1)}
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{arch}__{shape_name}.jsonl"), "a") as f:
        f.write(json.dumps(rec) + "\n")
    print(f"{arch}/{shape_name} [{variant}] "
          f"comp={r.t_compute*1e3:.1f}ms mem={r.t_memory*1e3:.1f}ms "
          f"coll={r.t_collective*1e3:.1f}ms → {r.bottleneck} "
          f"| step≥{r.step_time*1e3:.1f}ms mfu={r.mfu*100:.1f}% "
          f"| hbm/dev={r.memory_per_dev/2**30:.1f}GiB "
          f"({time.time()-t0:.0f}s)")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES), required=True)
    ap.add_argument("--shape", choices=list(SHAPES), required=True)
    ap.add_argument("--variant", default="baseline",
                    choices=list(VARIANTS), nargs="+")
    ap.add_argument("--note", default="")
    args = ap.parse_args()
    for v in (args.variant if isinstance(args.variant, list)
              else [args.variant]):
        run_variant(args.arch, args.shape, v, note=args.note)


if __name__ == "__main__":
    main()
