"""Serving driver: batched requests through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --requests 12 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

import json
import os

from ..configs.registry import ARCH_NAMES, get_config
from ..models import sharding, transformer
from ..obs import trace as obs_trace
from ..serving.engine import EngineConfig, Request, ServeEngine
from .mesh import make_host_mesh, make_production_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES), default="gemma-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--production", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default="",
                    help="write the Chrome trace here after the run "
                         "(requires REPRO_TRACE=1 or --telemetry on)")
    ap.add_argument("--metrics-out", default="",
                    help="write engine.metrics_snapshot() JSON here")
    ap.add_argument("--telemetry", choices=["auto", "on", "off"],
                    default="auto")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_production_mesh() if args.production else make_host_mesh()
    sharding.set_mesh(mesh)

    params = transformer.init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, EngineConfig(
        max_batch=args.max_batch, max_prompt=args.max_prompt,
        max_len=args.max_len, telemetry=args.telemetry))

    rng = np.random.default_rng(args.seed)
    for uid in range(args.requests):
        T = int(rng.integers(3, args.max_prompt // 2))
        prompt = rng.integers(1, cfg.vocab, size=T).astype(np.int32)
        engine.submit(Request(uid=uid, prompt=prompt,
                              max_new_tokens=args.max_new,
                              temperature=args.temperature, seed=uid))

    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)  stats={engine.stats}")
    for r in done[: 4]:
        print(f"  req {r.uid}: prompt[:4]={list(r.prompt[:4])} "
              f"→ {r.output[:8]}…")
    snap = engine.metrics_snapshot()
    ttft = snap["engine"]["histograms"].get("serve_ttft_s", {})
    if ttft.get("count"):
        tps = snap["engine"]["histograms"]["serve_tokens_per_s"]
        print(f"  ttft p50 {ttft['p50']*1e3:.1f}ms p99 {ttft['p99']*1e3:.1f}"
              f"ms  per-req tok/s p50 {tps['p50']:.1f}")
    if args.metrics_out:
        d = os.path.dirname(args.metrics_out)
        if d:
            os.makedirs(d, exist_ok=True)
        with open(args.metrics_out, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True, default=str)
        print(f"  metrics snapshot → {args.metrics_out}")
    if args.trace_out:
        obs_trace.export_chrome_trace(args.trace_out)
        print(f"  chrome trace → {args.trace_out}")
    return done


if __name__ == "__main__":
    main()
