"""Serving driver: batched requests through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --requests 12 --max-new 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs.registry import ARCH_NAMES, get_config
from ..models import sharding, transformer
from ..serving.engine import EngineConfig, Request, ServeEngine
from .mesh import make_host_mesh, make_production_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES), default="gemma-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--production", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_production_mesh() if args.production else make_host_mesh()
    sharding.set_mesh(mesh)

    params = transformer.init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, EngineConfig(
        max_batch=args.max_batch, max_prompt=args.max_prompt,
        max_len=args.max_len))

    rng = np.random.default_rng(args.seed)
    for uid in range(args.requests):
        T = int(rng.integers(3, args.max_prompt // 2))
        prompt = rng.integers(1, cfg.vocab, size=T).astype(np.int32)
        engine.submit(Request(uid=uid, prompt=prompt,
                              max_new_tokens=args.max_new,
                              temperature=args.temperature, seed=uid))

    t0 = time.perf_counter()
    done = engine.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)  stats={engine.stats}")
    for r in done[: 4]:
        print(f"  req {r.uid}: prompt[:4]={list(r.prompt[:4])} "
              f"→ {r.output[:8]}…")
    return done


if __name__ == "__main__":
    main()
