"""Step builders shared by dryrun/train/serve: the jitted programs plus
their (abstract inputs, shardings) for a given (arch, shape, mesh).

All builders work on ShapeDtypeStructs only — no allocation — so the same
code path serves the 512-device dry-run and real launches.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import SHAPES, input_specs
from ..models import sharding, transformer
from ..training.optimizer import OptimizerConfig
from ..training.train_loop import TrainConfig, make_train_step


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def batch_shardings(mesh, specs: dict):
    """tokens/labels/mask [B, T] → batch over (pod, data); embeds likewise;
    M-RoPE positions [3, B, T] → batch on axis 1.  Non-divisible dims
    (e.g. long_500k batch 1) fall back to replication."""
    rules = sharding.logical_to_spec
    out = {}
    for name, s in specs.items():
        if name == "positions":
            spec = P(None, *rules(("batch",)))
        elif s.ndim == 3:
            spec = P(*rules(("batch",)), None, None)
        else:
            spec = P(*rules(("batch",)), None)
        out[name] = _ns(mesh, sharding.sanitize_spec(mesh, spec, s.shape))
    return out


def opt_state_shardings(mesh, params_abs, params_sh):
    """mu/nu mirror the param shardings; counters replicate."""
    return {"mu": params_sh, "nu": params_sh,
            "count": _ns(mesh, P())}


# ---------------------------------------------------------------------------


def build_train_step(cfg, shape_name: str, mesh, *,
                     microbatches: int = 1, grad_compress: bool = False,
                     xent_chunk: int = 512):
    """Returns (fn, abstract_args, in_shardings).

    fn(state, batch) -> (state, metrics); state = {params, opt, step}."""
    sharding.set_mesh(mesh)
    step_kind, specs = input_specs(cfg, shape_name)
    assert step_kind == "train", shape_name

    tcfg = TrainConfig(opt=OptimizerConfig(), microbatches=microbatches,
                       grad_compress=grad_compress, xent_chunk=xent_chunk)
    loss_fn = lambda p, b: transformer.lm_loss(p, b, cfg,
                                               xent_chunk=xent_chunk)
    step = make_train_step(loss_fn, tcfg)

    params_abs = transformer.abstract_params(cfg)
    if cfg.param_dtype != jnp.float32:  # §Perf params_bf16 variant
        params_abs = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, cfg.param_dtype)
            if jnp.issubdtype(p.dtype, jnp.floating) else p, params_abs)
    opt_abs = {"mu": jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params_abs),
        "nu": jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params_abs),
        "count": jax.ShapeDtypeStruct((), jnp.int32)}
    state_abs = {"params": params_abs, "opt": opt_abs,
                 "step": jax.ShapeDtypeStruct((), jnp.int32)}
    if grad_compress:
        state_abs["compress"] = {"error": jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(
                p.shape if p.size >= 1024 else (), jnp.float32), params_abs)}

    params_sh = sharding.param_shardings(mesh, params_abs)
    state_sh = {"params": params_sh,
                "opt": opt_state_shardings(mesh, params_abs, params_sh),
                "step": _ns(mesh, P())}
    if grad_compress:
        state_sh["compress"] = {"error": jax.tree.map(
            lambda p, s: s if p.size >= 1024 else _ns(mesh, P()),
            params_abs, params_sh)}

    batch_abs = specs
    batch_sh = batch_shardings(mesh, specs)
    return step, (state_abs, batch_abs), (state_sh, batch_sh)


def _serving_params_abs(cfg):
    """Abstract params for serving steps: packed 6-bit codes when the
    config carries the paper's quant (decode is weight-HBM-bound; the
    packed form is the technique's serving win)."""
    params_abs = transformer.abstract_params(cfg)
    if cfg.quant == "logq6":
        from ..serving.quantize import abstract_quantized_params
        return abstract_quantized_params(params_abs)
    return params_abs


def build_prefill_step(cfg, shape_name: str, mesh, *, cache_dtype=jnp.bfloat16):
    """fn(params, inputs_dict) -> (last_hidden, cache)."""
    sharding.set_mesh(mesh)
    step_kind, specs = input_specs(cfg, shape_name)
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]

    def step(params, batch):
        inputs = batch["tokens"] if cfg.embed_inputs else batch["embeds"]
        cache = transformer.init_cache(cfg, B, S, cache_dtype)
        last, new_cache = transformer.prefill(
            params, inputs, cfg, cache, positions=batch.get("positions"))
        return last, new_cache

    params_abs = _serving_params_abs(cfg)
    params_sh = sharding.param_shardings(mesh, params_abs)
    batch_sh = batch_shardings(mesh, specs)
    return step, (params_abs, specs), (params_sh, batch_sh)


def build_decode_step(cfg, shape_name: str, mesh, *, cache_dtype=jnp.bfloat16):
    """fn(params, cache, batch) -> (logits, cache').  One new token against
    a seq_len-deep cache — the assigned decode_*/long_* cells."""
    sharding.set_mesh(mesh)
    step_kind, specs = input_specs(cfg, shape_name)
    sh = SHAPES[shape_name]
    B, S = sh["global_batch"], sh["seq_len"]

    def step(params, cache, batch):
        inputs = batch["tokens"] if cfg.embed_inputs else batch["embeds"]
        return transformer.decode_step(params, inputs, cfg, cache,
                                       positions=batch.get("positions"))

    params_abs = _serving_params_abs(cfg)
    cache_abs = transformer.abstract_cache(cfg, B, S, cache_dtype)
    params_sh = sharding.param_shardings(mesh, params_abs)
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    cache_sh = jax.tree.map(
        lambda spec, leaf: _ns(mesh,
                               sharding.sanitize_spec(mesh, spec, leaf.shape)),
        sharding.cache_specs(cache_abs, B, dp), cache_abs)
    batch_sh = batch_shardings(mesh, specs)
    return step, (params_abs, cache_abs, specs), \
        (params_sh, cache_sh, batch_sh)


def build_step(cfg, shape_name: str, mesh, **kw):
    kind = SHAPES[shape_name]["step"]
    if kind == "train":
        return "train", build_train_step(cfg, shape_name, mesh, **kw)
    if kind == "prefill":
        return "prefill", build_prefill_step(cfg, shape_name, mesh)
    return "decode", build_decode_step(cfg, shape_name, mesh)
