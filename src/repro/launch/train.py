"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch gemma-2b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On the container this runs reduced configs on the host mesh; on a real
cluster the same driver runs full configs on the production mesh
(--production).  Restart the command after a crash and it resumes from the
latest checkpoint (runtime/checkpoint.py), on whatever device count the
restarted world has (resharding restore).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..configs.registry import ARCH_NAMES, get_config
from ..data.pipeline import DataConfig, ShardedLoader
from ..models import sharding, transformer
from ..obs import metrics as obs_metrics
from ..runtime.checkpoint import CheckpointManager
from ..runtime.monitor import HeartbeatMonitor
from ..training.optimizer import OptimizerConfig
from ..training.train_loop import TrainConfig, init_train_state, train
from .mesh import make_host_mesh, make_production_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_NAMES), default="gemma-2b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--production", action="store_true",
                    help="use make_production_mesh() (real cluster)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true",
                    help="log-quant EF gradient compression (beyond-paper)")
    ap.add_argument("--quant", choices=["none", "logq6"], default="none")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.quant != "none":
        import dataclasses
        cfg = dataclasses.replace(cfg, quant=args.quant)

    mesh = make_production_mesh() if args.production else make_host_mesh()
    sharding.set_mesh(mesh)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(mesh.shape)}")

    loader = ShardedLoader(DataConfig(
        seq_len=args.seq, global_batch=args.batch, vocab=cfg.vocab,
        seed=args.seed, n_hosts=jax.process_count(),
        host_id=jax.process_index()))

    tcfg = TrainConfig(
        opt=OptimizerConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                            total_steps=args.steps),
        microbatches=args.microbatches, grad_compress=args.grad_compress,
        log_every=args.log_every,
        xent_chunk=min(512, args.seq))
    loss_fn = lambda p, b: transformer.lm_loss(p, b, cfg,
                                               xent_chunk=tcfg.xent_chunk)

    hooks = []
    start_step, state = 0, None
    monitor = HeartbeatMonitor([f"host{i}" for i in
                                range(jax.process_count())])

    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, keep=3)
        latest = mgr.latest_step()
        params0 = transformer.init_params(cfg, jax.random.PRNGKey(args.seed))
        if latest is not None:
            tpl = jax.eval_shape(
                lambda: init_train_state(params0, tcfg))
            state, start_step = mgr.restore(tpl)
            print(f"resumed from step {start_step}")
        hooks.append(mgr.hook(args.ckpt_every))
        params = params0
    else:
        params = transformer.init_params(cfg, jax.random.PRNGKey(args.seed))

    def progress(step, st, metrics):
        print(f"step {step:5d}  loss {metrics['loss']:.4f}  "
              f"gnorm {metrics['grad_norm']:.3f}  "
              f"wall {metrics['wall_s']:.1f}s")
    hooks.append(progress)

    # heartbeats + the step-time histogram are fed from train()'s single
    # per-step event stream (not a separate hook clock)
    registry = obs_metrics.MetricsRegistry()
    state, history = train(loss_fn, params, loader, tcfg,
                           num_steps=args.steps - start_step,
                           start_step=start_step, state=state, hooks=hooks,
                           metrics=registry, monitor=monitor,
                           host=f"host{jax.process_index()}")
    if args.ckpt_dir:
        mgr.save(int(state["step"]), state, sync=True)
    print(f"final loss {history[-1]['loss']:.4f} "
          f"(from {history[0]['loss']:.4f})")
    hist = registry.snapshot()["histograms"].get("train_step_s")
    if hist and hist["count"]:
        rep = monitor.report(int(state["step"]))
        print(f"step time p50 {hist['p50']*1e3:.1f}ms p99 "
              f"{hist['p99']*1e3:.1f}ms over {hist['count']} steps; "
              f"stragglers={list(rep.stragglers)} missing={rep.missing}")
    return history


if __name__ == "__main__":
    main()
