"""Model zoo: transformer assembly + mixers + CNN substrate."""
from . import attention, griffin, layers, moe, rwkv, sharding, transformer
