"""GQA/MQA attention mixer with RoPE/M-RoPE, QKV bias, windows and KV cache.

Cache layouts:
  * global ('attn') layers: [B, max_len, Hkv, hd], written at `index`.
  * 'local' layers: ring buffer of size `window` — decode writes at
    index % window and attends with key-position offsets so never-written
    slots (absolute position < 0) are masked.  This is what makes the
    gemma3/recurrentgemma long_500k cells sub-quadratic in cache memory.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels import ops
from . import sharding
from .layers import _init, apply_rope, dense


def attn_init(key, cfg):
    ks = jax.random.split(key, 4)
    D = cfg.d_model
    p = {"wq": _init(ks[0], (D, cfg.q_dim)),
         "wk": _init(ks[1], (D, cfg.kv_dim)),
         "wv": _init(ks[2], (D, cfg.kv_dim)),
         "wo": _init(ks[3], (cfg.q_dim, D))}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,))
        p["bk"] = jnp.zeros((cfg.kv_dim,))
        p["bv"] = jnp.zeros((cfg.kv_dim,))
    return p


def kv_cache_len(cfg, kind, max_len):
    if kind == "local" and cfg.attn_window is not None:
        return min(max_len, cfg.attn_window)
    return max_len


def init_kv_cache(cfg, kind, batch, max_len, dtype=jnp.bfloat16):
    S = kv_cache_len(cfg, kind, max_len)
    return {"k": jnp.zeros((batch, S, cfg.n_kv_heads, cfg.head_dim), dtype),
            "v": jnp.zeros((batch, S, cfg.n_kv_heads, cfg.head_dim), dtype)}


def _qkv(p, h, cfg, positions):
    B, T, _ = h.shape
    q = dense({"w": p["wq"], **({"b": p["bq"]} if "bq" in p else {})}, h, cfg)
    k = dense({"w": p["wk"], **({"b": p["bk"]} if "bk" in p else {})}, h, cfg)
    v = dense({"w": p["wv"], **({"b": p["bv"]} if "bv" in p else {})}, h, cfg)
    q = q.reshape(B, T, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
    if cfg.attn_shard == "heads":
        # Megatron-style TP: heads over the model axis, head_dim whole.
        # Without this the projections' column sharding splits head_dim,
        # and the score einsum's contraction emits partial-sum all-reduces
        # of [B,H,Tq,block] — the dominant collective in the baseline.
        q = sharding.constrain(q, ("batch", None, "tensor", None))
        k = sharding.constrain(k, ("batch", None, "tensor", None))
        v = sharding.constrain(v, ("batch", None, "tensor", None))
    elif cfg.attn_shard == "seq" and T > 1:
        # sequence-parallel attention: queries sharded over model on T,
        # k/v whole (cheap gather for MQA/GQA small kv_dim) — scores and
        # softmax are fully local, no attention collectives at all.
        q = sharding.constrain(q, ("batch", "tp_seq", None, None))
        k = sharding.constrain(k, ("batch", None, None, None))
        v = sharding.constrain(v, ("batch", None, None, None))
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def attention_mixer(p, h, cfg, *, kind="attn", positions, cache=None,
                    index=None):
    """h: [B, T, D] → (out [B, T, D], new_cache).

    Modes: cache=None (training); T>1 + cache (prefill: attend within the
    chunk, then populate the cache); T==1 + cache (decode at `index`)."""
    window = cfg.attn_window if kind == "local" else None
    q, k, v = _qkv(p, h, cfg, positions)
    B, T = h.shape[:2]
    acfg = ops.AttentionConfig(block_k=cfg.attn_block_k,
                               acc_dtype=cfg.attn_acc_dtype,
                               gqa_broadcast=cfg.gqa_broadcast)

    if cache is None:
        out = ops.attention(q, k, v, causal=True, window=window,
                            impl=cfg.attn_impl, config=acfg)
        new_cache = None

    elif T > 1:  # prefill
        out = ops.attention(q, k, v, causal=True, window=window,
                            impl=cfg.attn_impl, config=acfg)
        S = cache["k"].shape[1]
        if S >= T:  # cache holds the whole chunk
            ck = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
        else:       # ring smaller than the chunk: keep the last S tokens
            ck = k[:, T - S:].astype(cache["k"].dtype)
            cv = v[:, T - S:].astype(cache["v"].dtype)
        new_cache = {"k": ck, "v": cv}

    else:        # decode one token at absolute position `index`
        S = cache["k"].shape[1]
        is_ring = window is not None and S <= window
        slot = (index % S) if is_ring else index
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
        new_cache = {"k": ck, "v": cv}
        if is_ring:
            # unroll the ring into logical order (oldest first): the key at
            # array slot j has absolute position index - S + 1 + j after a
            # roll by -(slot+1); never-written slots land at positions < 0
            # and are masked by k_offset semantics.
            idxs = (jnp.arange(S) + slot + 1) % S
            ck_l = jnp.take(ck, idxs, axis=1)
            cv_l = jnp.take(cv, idxs, axis=1)
            dcfg = dataclasses.replace(acfg,
                                       block_k=min(cfg.attn_block_k, S))
            out = ops.attention(q, ck_l, cv_l, causal=True, window=window,
                                q_offset=index, k_offset=index - S + 1,
                                impl=cfg.attn_impl, config=dcfg)
        else:
            dcfg = dataclasses.replace(acfg,
                                       block_k=min(cfg.attn_block_k, S))
            out = ops.attention(q, ck, cv, causal=True, window=window,
                                q_offset=index, impl=cfg.attn_impl,
                                config=dcfg)

    if cfg.attn_shard == "heads":
        out = sharding.constrain(out, ("batch", None, "tensor", None))
    elif cfg.attn_shard == "seq" and T > 1:
        out = sharding.constrain(out, ("batch", "tp_seq", None, None))
    out = out.reshape(B, T, cfg.q_dim)
    return dense({"w": p["wo"]}, out, cfg), new_cache
