"""CNN substrate in JAX — the networks the paper benchmarks (VGG-16,
MobileNet v1, ResNet-34, SqueezeNet) with optional base-√2 log fake-quant
on conv weights *and* post-ReLU activations (paper §3: ReLU removes the
need for an activation sign bit).

These are real, trainable JAX models.  Two orthogonal knobs:

  * ``quant="logq6"`` inserts `fake_log_quant` (straight-through estimator)
    on conv/dense weights and post-ReLU activations — the QAT path, fully
    differentiable.
  * ``conv_impl="pallas"|"pallas_im2col"|"blockwise"|"ref"|"auto"`` routes
    every conv through the unified log-domain dispatcher
    `kernels/ops.conv2d`: weights are packed int8 log codes (once at load
    via `serving.quantize.quantize_cnn_params`, or on the fly) and the conv
    executes against the codes — the true deployed numerics, top tier of
    the three-tier conv stack (fused implicit-im2col Pallas kernel with
    autotuned block sizes ↔ explicit-im2col fallback ↔ blockwise fallback ↔
    `core/pe_grid.py` hardware oracle).  Inference-only: packing is not
    differentiable, so training keeps ``conv_impl=None`` (fake-quant).

Layer lists intentionally mirror `core/accelerator.py` so the analytical
dataflow model and the executable model describe the same networks.
"""

from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp

from ..core.logquant import DEFAULT as LOGQ_DEFAULT
from ..core.logquant import (LogQuantConfig, QuantizedTensor, fake_log_quant,
                             quantize_tensor)
from ..kernels import ops as kops

# ---------------------------------------------------------------------------
# quant-aware primitives
# ---------------------------------------------------------------------------


def _maybe_fq(w, quant: str | None, cfg: LogQuantConfig):
    return fake_log_quant(w, cfg) if quant == "logq6" else w


def conv2d(p, x, *, stride=1, pad="SAME", quant=None, qcfg=LOGQ_DEFAULT,
           groups=1, conv_impl=None, interpret=None):
    """x: [B, H, W, Cin]; p['w']: [K, K, Cin//groups, Cout] (float array or
    packed `QuantizedTensor`).

    With ``conv_impl`` set (or a pre-packed weight), the conv dispatches to
    `kernels.ops.conv2d` on int8 log codes ("pallas" = the fused
    implicit-im2col kernel, block sizes from the autotuning table);
    otherwise it is the fake-quant `lax.conv` QAT path.
    """
    w = p["w"]
    if _CONV_SHAPE_TRACE is not None:
        hwio = tuple(w.shape)  # QuantizedTensor.shape is the logical HWIO
        _CONV_SHAPE_TRACE.append(dict(
            B=int(x.shape[0]), H=int(x.shape[1]), W=int(x.shape[2]),
            C=int(x.shape[3]), K=int(hwio[0]), Cout=int(hwio[-1]),
            stride=int(stride), padding=pad, groups=int(groups)))
    if conv_impl is not None or isinstance(w, QuantizedTensor):
        qt = w if isinstance(w, QuantizedTensor) else quantize_tensor(w, qcfg)
        y = kops.conv2d(x, qt, stride=stride, padding=pad, groups=groups,
                        impl=conv_impl or "auto", interpret=interpret,
                        out_dtype=x.dtype)
    else:
        w = _maybe_fq(w, quant, qcfg)
        y = jax.lax.conv_general_dilated(
            x, w, window_strides=(stride, stride), padding=pad,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=groups)
    if "b" in p:
        y = y + p["b"]
    return y


def conv_init(key, k, cin, cout, groups=1, dtype=jnp.float32):
    fan_in = k * k * cin // groups
    w = jax.random.normal(key, (k, k, cin // groups, cout), dtype)
    return {"w": w * (2.0 / fan_in) ** 0.5, "b": jnp.zeros((cout,), dtype)}


def relu_q(x, quant=None, qcfg=LOGQ_DEFAULT):
    """ReLU then (optionally) log-requantize — the paper's post-processing
    block: ReLU + log-table requantization before writing back to DDR."""
    x = jax.nn.relu(x)
    return _maybe_fq(x, quant, qcfg) if quant == "logq6" else x


def avgpool_global(x):
    return jnp.mean(x, axis=(1, 2))


def maxpool(x, k=2, s=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, s, s, 1), "VALID")


# ---------------------------------------------------------------------------
# VGG-16
# ---------------------------------------------------------------------------

_VGG_PLAN = [  # (Cout, pool_after)
    (64, False), (64, True), (128, False), (128, True),
    (256, False), (256, False), (256, True),
    (512, False), (512, False), (512, True),
    (512, False), (512, False), (512, True),
]


def vgg16_init(key, *, n_classes=1000, cin=3, width_mult=1.0):
    keys = jax.random.split(key, len(_VGG_PLAN) + 1)
    params, c = [], cin
    for i, (cout, _) in enumerate(_VGG_PLAN):
        cout = max(8, int(cout * width_mult))
        params.append(conv_init(keys[i], 3, c, cout))
        c = cout
    head = {"w": jax.random.normal(keys[-1], (c, n_classes)) * (1 / c) ** 0.5,
            "b": jnp.zeros((n_classes,))}
    return {"convs": params, "head": head}


def vgg16_apply(params, x, *, quant=None, qcfg=LOGQ_DEFAULT, conv_impl=None,
                interpret=None):
    cv = functools.partial(conv2d, quant=quant, qcfg=qcfg,
                           conv_impl=conv_impl, interpret=interpret)
    for p, (_, pool) in zip(params["convs"], _VGG_PLAN):
        x = relu_q(cv(p, x), quant, qcfg)
        if pool and min(x.shape[1], x.shape[2]) >= 2:
            x = maxpool(x)
    x = avgpool_global(x)
    return x @ params["head"]["w"] + params["head"]["b"]


# ---------------------------------------------------------------------------
# MobileNet v1 (depthwise separable — the paper's separable mode)
# ---------------------------------------------------------------------------

_MBN_PAIRS = [(64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2)] + \
             [(512, 1)] * 5 + [(1024, 2), (1024, 1)]


def mobilenet_v1_init(key, *, n_classes=1000, cin=3, width_mult=1.0):
    n = 1 + 2 * len(_MBN_PAIRS) + 1
    keys = jax.random.split(key, n)
    c0 = max(8, int(32 * width_mult))
    params = {"stem": conv_init(keys[0], 3, cin, c0), "pairs": []}
    c = c0
    for i, (cout, _) in enumerate(_MBN_PAIRS):
        cout = max(8, int(cout * width_mult))
        dw = conv_init(keys[1 + 2 * i], 3, c, c, groups=c)
        pw = conv_init(keys[2 + 2 * i], 1, c, cout)
        params["pairs"].append({"dw": dw, "pw": pw})
        c = cout
    params["head"] = {"w": jax.random.normal(keys[-1], (c, n_classes))
                      * (1 / c) ** 0.5, "b": jnp.zeros((n_classes,))}
    return params


def mobilenet_v1_apply(params, x, *, quant=None, qcfg=LOGQ_DEFAULT,
                       conv_impl=None, interpret=None):
    cv = functools.partial(conv2d, quant=quant, qcfg=qcfg,
                           conv_impl=conv_impl, interpret=interpret)
    x = relu_q(cv(params["stem"], x, stride=2), quant, qcfg)
    for pair, (_, stride) in zip(params["pairs"], _MBN_PAIRS):
        c = x.shape[-1]
        x = relu_q(cv(pair["dw"], x, stride=stride, groups=c), quant, qcfg)
        x = relu_q(cv(pair["pw"], x), quant, qcfg)
    x = avgpool_global(x)
    return x @ params["head"]["w"] + params["head"]["b"]


# ---------------------------------------------------------------------------
# ResNet-34
# ---------------------------------------------------------------------------

_R34_STAGES = [(64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)]


def resnet34_init(key, *, n_classes=1000, cin=3, width_mult=1.0):
    blocks = sum(b for _, b, _ in _R34_STAGES)
    keys = iter(jax.random.split(key, 2 + 3 * blocks))
    c0 = max(8, int(64 * width_mult))
    params = {"stem": conv_init(next(keys), 5, cin, c0), "stages": []}
    cin_cur = c0
    for cout, nblocks, first_stride in _R34_STAGES:
        cout = max(8, int(cout * width_mult))
        stage = []
        for b in range(nblocks):
            st = first_stride if b == 0 else 1
            blk = {"c1": conv_init(next(keys), 3, cin_cur, cout),
                   "c2": conv_init(next(keys), 3, cout, cout)}
            if st != 1 or cin_cur != cout:
                blk["proj"] = conv_init(next(keys), 1, cin_cur, cout)
            stage.append((blk, st))
            cin_cur = cout
        params["stages"].append(stage)
    params["head"] = {"w": jax.random.normal(next(keys), (cin_cur, n_classes))
                      * (1 / cin_cur) ** 0.5, "b": jnp.zeros((n_classes,))}
    return params


def resnet34_apply(params, x, *, quant=None, qcfg=LOGQ_DEFAULT,
                   conv_impl=None, interpret=None):
    cv = functools.partial(conv2d, quant=quant, qcfg=qcfg,
                           conv_impl=conv_impl, interpret=interpret)
    x = relu_q(cv(params["stem"], x, stride=2), quant, qcfg)
    if min(x.shape[1], x.shape[2]) >= 2:
        x = maxpool(x)
    for stage in params["stages"]:
        for blk, st in stage:
            y = relu_q(cv(blk["c1"], x, stride=st), quant, qcfg)
            y = cv(blk["c2"], y)
            sc = cv(blk["proj"], x, stride=st) if "proj" in blk else x
            x = relu_q(y + sc, quant, qcfg)
    x = avgpool_global(x)
    return x @ params["head"]["w"] + params["head"]["b"]


# ---------------------------------------------------------------------------
# SqueezeNet v1.0 (Fig-1 net)
# ---------------------------------------------------------------------------

_FIRES = [(96, 16, 64), (128, 16, 64), (128, 32, 128), (256, 32, 128),
          (256, 48, 192), (384, 48, 192), (384, 64, 256), (512, 64, 256)]


def squeezenet_init(key, *, n_classes=1000, cin=3, width_mult=1.0):
    keys = iter(jax.random.split(key, 2 + 3 * len(_FIRES)))
    m = lambda c: max(4, int(c * width_mult))
    params = {"stem": conv_init(next(keys), 5, cin, m(96)), "fires": []}
    for cin_f, sq, ex in _FIRES:
        params["fires"].append({
            "squeeze": conv_init(next(keys), 1, m(cin_f), m(sq)),
            "e1": conv_init(next(keys), 1, m(sq), m(ex)),
            "e3": conv_init(next(keys), 3, m(sq), m(ex))})
    params["final"] = conv_init(next(keys), 1, m(512), n_classes)
    return params


def squeezenet_apply(params, x, *, quant=None, qcfg=LOGQ_DEFAULT,
                     conv_impl=None, interpret=None):
    cv = functools.partial(conv2d, quant=quant, qcfg=qcfg,
                           conv_impl=conv_impl, interpret=interpret)
    x = relu_q(cv(params["stem"], x, stride=2), quant, qcfg)
    if min(x.shape[1], x.shape[2]) >= 2:
        x = maxpool(x, 3, 2)
    for i, fire in enumerate(params["fires"]):
        if i in (3, 7) and min(x.shape[1], x.shape[2]) >= 2:
            x = maxpool(x, 3, 2)
        s = relu_q(cv(fire["squeeze"], x), quant, qcfg)
        e1 = relu_q(cv(fire["e1"], s), quant, qcfg)
        e3 = relu_q(cv(fire["e3"], s), quant, qcfg)
        x = jnp.concatenate([e1, e3], axis=-1)
    x = relu_q(cv(params["final"], x), quant, qcfg)
    return avgpool_global(x)


# ---------------------------------------------------------------------------
# registry + loss
# ---------------------------------------------------------------------------

CNNS = {
    "vgg16": (vgg16_init, vgg16_apply),
    "mobilenet_v1": (mobilenet_v1_init, mobilenet_v1_apply),
    "resnet34": (resnet34_init, resnet34_apply),
    "squeezenet": (squeezenet_init, squeezenet_apply),
}

CNN_ZOO = CNNS  # the paper's four networks — the warm-start tuning target


# ---------------------------------------------------------------------------
# conv-shape walker (feeds the packaged autotune warm-start tier)
# ---------------------------------------------------------------------------

_CONV_SHAPE_TRACE: list | None = None


@contextlib.contextmanager
def _capture_conv_shapes(records: list):
    global _CONV_SHAPE_TRACE
    prev = _CONV_SHAPE_TRACE
    _CONV_SHAPE_TRACE = records
    try:
        yield records
    finally:
        _CONV_SHAPE_TRACE = prev


def trace_conv_shapes(name: str, *, batch=1, img=224, n_classes=1000, cin=3,
                      width_mult=1.0) -> list[dict]:
    """Every conv dispatch of one zoo network, as launch-geometry records
    ``{B, H, W, C, K, Cout, stride, padding, groups}`` in call order.

    Shape tracing only: `init` runs *inside* `jax.eval_shape` (so python
    strides in the param tree stay static) and no parameters or
    activations are ever materialised — walking all four networks at the
    paper's 224 px takes seconds, not a forward pass."""
    init, apply = CNNS[name]
    records: list[dict] = []

    def run(key, x):
        return apply(init(key, n_classes=n_classes, cin=cin,
                          width_mult=width_mult), x)

    with _capture_conv_shapes(records):
        jax.eval_shape(run, jax.ShapeDtypeStruct((2,), jnp.uint32),
                       jax.ShapeDtypeStruct((batch, img, img, cin),
                                            jnp.float32))
    return records


def zoo_conv_shapes(*, batch=1, img=224, n_classes=1000, cin=3,
                    width_mult=1.0) -> list[dict]:
    """Deduped union of conv launch shapes across the whole zoo — the
    shape list the packaged autotune tier must cover (each record gains a
    ``nets`` list naming the networks that dispatch it)."""
    seen: dict[tuple, dict] = {}
    for name in CNNS:
        for r in trace_conv_shapes(name, batch=batch, img=img,
                                   n_classes=n_classes, cin=cin,
                                   width_mult=width_mult):
            sig = tuple(sorted((k, str(v)) for k, v in r.items()))
            if sig not in seen:
                seen[sig] = dict(r, nets=[name])
            elif name not in seen[sig]["nets"]:
                seen[sig]["nets"].append(name)
    return list(seen.values())


def make_cnn(name: str, key, *, n_classes=1000, cin=3, width_mult=1.0,
             quant=None, qcfg=LOGQ_DEFAULT, conv_impl=None, interpret=None):
    init, apply = CNNS[name]
    params = init(key, n_classes=n_classes, cin=cin, width_mult=width_mult)
    return params, functools.partial(apply, quant=quant, qcfg=qcfg,
                                     conv_impl=conv_impl, interpret=interpret)


def cnn_loss(apply_fn, params, batch):
    logits = apply_fn(params, batch["images"])
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)
    acc = jnp.mean(jnp.argmax(logits, -1) == batch["labels"])
    return jnp.mean(nll), {"acc": acc}
