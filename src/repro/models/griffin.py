"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block:  x → (gelu gate branch) ⊙ (proj → causal conv1d(w=4) → RG-LRU) → out

RG-LRU:  r_t = σ(W_r x_t),  i_t = σ(W_i x_t)
         log a_t = −c · softplus(Λ) ⊙ r_t           (c = 8)
         h_t = a_t ⊙ h_{t−1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)

Train/prefill uses `jax.lax.associative_scan` over the diagonal linear
recurrence (parallel in T); decode carries (h, conv window) — O(1) state,
which is what makes the long_500k cell run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import _init

C_RGLRU = 8.0


def griffin_init(key, cfg):
    ks = jax.random.split(key, 7)
    D = cfg.d_model
    W = cfg.lru_width or D
    return {
        "w_gate": _init(ks[0], (D, W)),     # gelu branch
        "w_x": _init(ks[1], (D, W)),        # recurrent branch input
        "conv_w": _init(ks[2], (cfg.conv1d_width, W), scale=0.3),
        "conv_b": jnp.zeros((W,)),
        "w_r": _init(ks[3], (W, W), scale=0.01),
        "w_i": _init(ks[4], (W, W), scale=0.01),
        "lam": jnp.full((W,), 2.0),         # softplus(2) ≈ 2.1 → a ≈ exp(-17r)
        "w_out": _init(ks[5], (W, D)),
    }


def griffin_state_init(cfg, batch):
    W = cfg.lru_width or cfg.d_model
    return {"h": jnp.zeros((batch, W), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv1d_width - 1, W), jnp.float32)}


def _causal_conv1d(x, w, b, prev=None):
    """x: [B, T, W]; w: [K, W] depthwise; prev: [B, K-1, W] carried context."""
    K = w.shape[0]
    B, T, Wd = x.shape
    if prev is None:
        prev = jnp.zeros((B, K - 1, Wd), x.dtype)
    xp = jnp.concatenate([prev.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + T] * w[i].astype(x.dtype) for i in range(K))
    return out + b.astype(x.dtype), xp[:, -(K - 1):]


def _rglru(x, loga, h0=None):
    """Diagonal linear recurrence via associative scan.

    x: [B, T, W] already gated by i_t; loga: [B, T, W] (≤ 0)."""
    f32 = jnp.float32
    a = jnp.exp(loga.astype(f32))
    mult = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    b = mult * x.astype(f32)
    if h0 is not None:
        # fold the carried state into the first step: h_1 = a_1 h_0 + b_1
        b = b.at[:, 0].add(a[:, 0] * h0.astype(f32))

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def griffin_mixer(p, x, cfg, state=None):
    """x: [B, T, D] → (out [B, T, D], new_state)."""
    B, T, D = x.shape
    gate = jax.nn.gelu(x @ p["w_gate"].astype(x.dtype))
    u = x @ p["w_x"].astype(x.dtype)
    prev = state["conv"] if state is not None else None
    u, conv_carry = _causal_conv1d(u, p["conv_w"], p["conv_b"], prev)

    r = jax.nn.sigmoid(u.astype(jnp.float32) @ p["w_r"])
    i = jax.nn.sigmoid(u.astype(jnp.float32) @ p["w_i"])
    loga = -C_RGLRU * jax.nn.softplus(p["lam"])[None, None] * r
    gated = i * u.astype(jnp.float32)

    h0 = state["h"] if state is not None else None
    h = _rglru(gated, loga, h0)

    out = (gate * h.astype(x.dtype)) @ p["w_out"].astype(x.dtype)
    new_state = None
    if state is not None:
        new_state = {"h": h[:, -1], "conv": conv_carry.astype(jnp.float32)}
    return out, new_state
