"""Primitive layers: norms, dense (+ optional log-quantized weights),
rotary embeddings (incl. M-RoPE), FFNs, embedding table.

Parameters are plain nested dicts of jnp arrays.  Every dense weight has a
canonical [in, out] layout so the sharding rules in `models/sharding.py`
apply uniformly.  When `cfg.quant == "logq6"`, matmuls fake-quantize weights
onto the base-√2 grid (QAT / accuracy studies) — the serving path swaps in
`kernels.ops.log_matmul` against pre-packed codes (see `serving/engine.py`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.logquant import LogQuantConfig, QuantizedTensor, fake_log_quant
from repro.kernels.ops import log_matmul


def _init(key, shape, scale=None, dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / (shape[0] ** 0.5)
    return jax.random.normal(key, shape, dtype) * scale


def dense_init(key, d_in, d_out, dtype=jnp.float32):
    return {"w": _init(key, (d_in, d_out), dtype=dtype)}


def dense_bias_init(key, d_in, d_out, dtype=jnp.float32):
    return {"w": _init(key, (d_in, d_out), dtype=dtype),
            "b": jnp.zeros((d_out,), dtype)}


def dense(p, x, cfg=None):
    """x @ w (+ b).  Honors cfg.quant: fake-quant (train/QAT) or a packed
    QuantizedTensor left by the serving quantizer."""
    w = p["w"]
    if isinstance(w, QuantizedTensor):
        y = log_matmul(x, w)
    else:
        if cfg is not None and cfg.quant == "logq6":
            w = fake_log_quant(w, LogQuantConfig())
        y = x @ w.astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def layernorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps=1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def norm_init(cfg, d=None):
    d = d or cfg.d_model
    return rmsnorm_init(d) if cfg.norm == "rmsnorm" else layernorm_init(d)


def norm(cfg, p, x):
    return rmsnorm(p, x) if cfg.norm == "rmsnorm" else layernorm(p, x)


# ---------------------------------------------------------------------------
# rotary position embeddings (+ M-RoPE for qwen2-vl)
# ---------------------------------------------------------------------------


def _rope_angles(positions, head_dim, theta):
    """positions: [B, T] → cos/sin [B, T, head_dim/2]."""
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta=10_000.0, mrope_sections=None):
    """x: [B, T, H, D]; positions: [B, T] (or [3, B, T] for M-RoPE).

    M-RoPE (Qwen2-VL): the head_dim/2 frequency channels are split into
    (t, h, w) sections, each rotated by its own position stream."""
    B, T, H, D = x.shape
    half = D // 2
    if mrope_sections is None:
        cos, sin = _rope_angles(positions, D, theta)
    else:
        assert sum(mrope_sections) == half, (mrope_sections, half)
        if positions.ndim == 2:  # text-only: reuse the same stream
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        coss, sins = [], []
        start = 0
        freq_full = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
        for i, sec in enumerate(mrope_sections):
            f = freq_full[start:start + sec]
            ang = positions[i][..., None].astype(jnp.float32) * f
            coss.append(jnp.cos(ang))
            sins.append(jnp.sin(ang))
            start += sec
        cos = jnp.concatenate(coss, -1)
        sin = jnp.concatenate(sins, -1)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], -1).astype(x.dtype)


# ---------------------------------------------------------------------------
# FFN (dense path; MoE lives in models/moe.py)
# ---------------------------------------------------------------------------


def ffn_init(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    D, F = cfg.d_model, cfg.d_ff
    if cfg.ffn in ("swiglu", "geglu"):
        return {"w1": _init(k1, (D, F)), "w3": _init(k3, (D, F)),
                "w2": _init(k2, (F, D))}
    return {"w1": _init(k1, (D, F)), "w2": _init(k2, (F, D))}


def ffn(p, x, cfg):
    if cfg.ffn == "swiglu":
        h = jax.nn.silu(dense({"w": p["w1"]}, x, cfg)) * \
            dense({"w": p["w3"]}, x, cfg)
    elif cfg.ffn == "geglu":
        h = jax.nn.gelu(dense({"w": p["w1"]}, x, cfg)) * \
            dense({"w": p["w3"]}, x, cfg)
    else:
        h = jax.nn.gelu(dense({"w": p["w1"]}, x, cfg))
    return dense({"w": p["w2"]}, h, cfg)


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------


def embed_init(key, cfg):
    # 1/√d keeps tied-unembed logits O(1) at init (loss starts at ≈ln V);
    # cfg.embed_scale (gemma) restores O(1) embeddings at the input side.
    p = {"table": _init(key, (cfg.vocab, cfg.d_model),
                        scale=cfg.d_model ** -0.5)}
    if not cfg.tie_embeddings:
        p["lm_head"] = _init(jax.random.fold_in(key, 1),
                             (cfg.d_model, cfg.vocab))
    return p


def embed(p, tokens, cfg):
    h = jnp.take(p["table"].astype(cfg.act_dtype), tokens, axis=0)
    if cfg.embed_scale:
        h = h * jnp.asarray(cfg.d_model ** 0.5, h.dtype)
    return h


def unembed(p, h, cfg):
    if cfg.tie_embeddings:
        w = p["table"].astype(h.dtype).T
        if cfg.quant == "logq6" and not isinstance(w, QuantizedTensor):
            pass  # tied table stays fp — quantizing it hurts embed lookups
        return h @ w
    return dense({"w": p["lm_head"]}, h, cfg)
