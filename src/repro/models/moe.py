"""GShard-style top-k Mixture-of-Experts FFN with grouped capacity-factor
dispatch.

Expert-parallel: experts shard over the 'tensor' mesh axis; dispatch/
combine are dense einsums against a one-hot, so GSPMD lowers the exchange
to all-to-all-ish collectives without ragged ops.

Tokens are routed within **groups** of `moe_group` tokens (GShard's group
dimension = the per-device token block).  Capacity is per group —
C = cf·G·K/E — so the dispatch tensor is [n_g, G, E, C] with total bytes
N·E·C_g instead of the ungrouped N·E·C_N (C grows with the token count:
ungrouped dispatch at 1M tokens is 160× larger and dominated the §Roofline
memory term of every MoE cell).

Router aux loss = load-balancing loss of Switch/GShard
(E · Σ_e fraction_tokens_e · mean_prob_e), computed globally.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import sharding
from .layers import _init

# per-group token block for routing; must divide the token count (falls
# back to one global group otherwise, e.g. tiny smoke configs)
DEFAULT_GROUP = 4096


def moe_init(key, cfg):
    ks = jax.random.split(key, 4)
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {"router": _init(ks[0], (D, E), scale=0.02)}
    if cfg.ffn in ("swiglu", "geglu"):
        p["moe_w1"] = _init(ks[1], (E, D, F))
        p["moe_w3"] = _init(ks[3], (E, D, F))
    else:
        p["moe_w1"] = _init(ks[1], (E, D, F))
    p["moe_w2"] = _init(ks[2], (E, F, D))
    return p


def _group_size(N: int) -> int:
    if N % DEFAULT_GROUP == 0:
        return DEFAULT_GROUP
    return N  # tiny configs: one group (ungrouped = old behaviour)


def moe_ffn(p, x, cfg, capacity: int | None = None):
    """x: [B, T, D] → (y: [B, T, D], aux_loss scalar)."""
    B, T, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * T
    f32 = jnp.float32
    xt = x.reshape(N, D)

    logits = (xt.astype(f32) @ p["router"].astype(f32))          # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)                # [N, K]
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    G = _group_size(N)
    n_g = N // G
    if capacity is None:
        if T == 1:   # decode: no capacity drops (every token must route)
            capacity = G
        else:
            capacity = int(cfg.capacity_factor * G * K / E) or 1
    C = max(1, min(capacity, G))

    # group the token axis: [n_g, G, ...]
    onehot = jax.nn.one_hot(gate_idx, E, dtype=f32).reshape(n_g, G, K, E)
    gate_g = gate_vals.reshape(n_g, G, K)
    xg = xt.reshape(n_g, G, D)
    xg = sharding.constrain(xg, ("batch", None, None))

    # position of each (token, k) within its expert's per-group queue
    flat = onehot.reshape(n_g, G * K, E)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(n_g, G, K, E)
    pos = jnp.sum(pos * onehot, axis=-1)                         # [n_g,G,K]
    keep = pos < C
    gate_g = gate_g * keep.astype(f32)

    slot_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=f32)
    dispatch = jnp.einsum("gnke,gnkc->gnec", onehot,
                          slot_oh * keep[..., None].astype(f32))
    combine = jnp.einsum("gnke,gnkc,gnk->gnec", onehot, slot_oh, gate_g)

    # dispatch: [n_g, E, C, D]; groups shard over batch, experts over model
    xe = jnp.einsum("gnec,gnd->gecd", dispatch.astype(x.dtype), xg)
    xe = sharding.constrain(xe, ("batch", "tensor", None, None))
    w1 = p["moe_w1"].astype(x.dtype)
    if cfg.ffn == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, w1))
        h = h * jnp.einsum("gecd,edf->gecf", xe, p["moe_w3"].astype(x.dtype))
    elif cfg.ffn == "geglu":
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xe, w1))
        h = h * jnp.einsum("gecd,edf->gecf", xe, p["moe_w3"].astype(x.dtype))
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", xe, w1))
    ye = jnp.einsum("gecf,efd->gecd", h, p["moe_w2"].astype(x.dtype))
    ye = sharding.constrain(ye, ("batch", "tensor", None, None))
    y = jnp.einsum("gnec,gecd->gnd", combine.astype(x.dtype), ye)

    # load-balancing aux loss (global)
    frac = jnp.mean(jnp.sum(onehot, axis=2).reshape(N, E), axis=0)
    mprob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * mprob) * cfg.router_aux_weight

    return y.reshape(B, T, D), aux.astype(f32)
