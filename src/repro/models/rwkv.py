"""RWKV6 ("Finch") layer: time-mix with data-dependent decay + channel-mix.

Faithful structure (arXiv:2404.05892): static token-shift interpolation
μ_{r,k,v,w,g}, projections r/k/v/g, a low-rank (LoRA) data-dependent decay
    log w_t = −exp(w0 + tanh(x_w A) B)   (≤ 0 per channel)
a per-head bonus u for the current token, the WKV recurrence (our
`kernels/wkv6`), per-head group-norm, and an output gate.  Channel-mix is
the squared-ReLU gated MLP of RWKV.

State per layer (decode): x_prev for both mixes [B, D] and the WKV matrix
state [B, H, K, V] — O(1) in sequence length (the long_500k cell).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from .layers import _init, dense


def _mm(x, w):
    """x @ w via layers.dense — supports packed QuantizedTensor weights."""
    return dense({"w": w}, x)


def rwkv_init(key, cfg):
    ks = jax.random.split(key, 12)
    D = cfg.d_model
    H = D // cfg.rwkv_head_size
    hs = cfg.rwkv_head_size
    L = cfg.rwkv_decay_lora
    F = cfg.d_ff
    return {
        # time-mix
        "mu": 0.5 * jnp.ones((5, D)),              # r, k, v, w, g shifts
        "wr": _init(ks[0], (D, D)), "wk": _init(ks[1], (D, D)),
        "wv": _init(ks[2], (D, D)), "wg": _init(ks[3], (D, D)),
        "wo": _init(ks[4], (D, D)),
        "w0": jnp.zeros((D,)) - 0.6,               # base decay
        "wA": _init(ks[5], (D, L), scale=0.01),
        "wB": _init(ks[6], (L, D), scale=0.01),
        "u": _init(ks[7], (H, hs), scale=0.5),
        "ln_x": jnp.ones((D,)),                    # per-head group norm scale
        # channel-mix
        "mu_c": 0.5 * jnp.ones((2, D)),            # k, r shifts
        "ck": _init(ks[8], (D, F)),
        "cv": _init(ks[9], (F, D)),
        "cr": _init(ks[10], (D, D)),
    }


def _token_shift(x, x_prev):
    """[B, T, D] → previous token's features (x_prev fills t = 0)."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def rwkv_state_init(cfg, batch):
    D = cfg.d_model
    H = D // cfg.rwkv_head_size
    hs = cfg.rwkv_head_size
    return {"x_prev_t": jnp.zeros((batch, D), jnp.float32),
            "x_prev_c": jnp.zeros((batch, D), jnp.float32),
            "wkv": jnp.zeros((batch, H, hs, hs), jnp.float32)}


def rwkv_time_mix(p, x, cfg, state=None):
    """x: [B, T, D] → (out, new_state_parts)."""
    B, T, D = x.shape
    H = D // cfg.rwkv_head_size
    hs = cfg.rwkv_head_size
    xp = state["x_prev_t"].astype(x.dtype) if state is not None \
        else jnp.zeros((B, D), x.dtype)
    xx = _token_shift(x, xp) - x
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xw, xg = (x + xx * mu[i] for i in range(5))

    r = _mm(xr, p["wr"]).reshape(B, T, H, hs)
    k = _mm(xk, p["wk"]).reshape(B, T, H, hs)
    v = _mm(xv, p["wv"]).reshape(B, T, H, hs)
    g = jax.nn.silu(_mm(xg, p["wg"]))

    # data-dependent decay (Finch): logw = -exp(w0 + tanh(xw A) B) ∈ (-inf, 0)
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["wA"]) @ p["wB"]
    logw = -jnp.exp(jnp.clip(p["w0"][None, None] + lora, -8.0, 2.0))
    logw = logw.reshape(B, T, H, hs)

    wkv_state = state["wkv"] if state is not None else None
    o, new_wkv = ops.wkv6(r, k, v, logw, p["u"],
                          state=wkv_state, impl="blockwise",
                          chunk=min(64, max(16, T)))
    o = o.reshape(B, T, D)

    # per-head group norm
    o32 = o.astype(jnp.float32).reshape(B, T, H, hs)
    mu_ = jnp.mean(o32, -1, keepdims=True)
    var = jnp.var(o32, -1, keepdims=True)
    o = ((o32 - mu_) * jax.lax.rsqrt(var + 1e-5)).reshape(B, T, D)
    o = (o * p["ln_x"][None, None]).astype(x.dtype)

    out = _mm(o * g, p["wo"])
    new_state = None
    if state is not None:
        new_state = {"x_prev_t": x[:, -1].astype(jnp.float32),
                     "wkv": new_wkv}
    return out, new_state


def rwkv_channel_mix(p, x, cfg, state=None):
    B, T, D = x.shape
    xp = state["x_prev_c"].astype(x.dtype) if state is not None \
        else jnp.zeros((B, D), x.dtype)
    xx = _token_shift(x, xp) - x
    mu = p["mu_c"].astype(x.dtype)
    xk = x + xx * mu[0]
    xr = x + xx * mu[1]
    k = jnp.square(jax.nn.relu(_mm(xk, p["ck"])))
    out = jax.nn.sigmoid(_mm(xr, p["cr"])) * _mm(k, p["cv"])
    new_state = None
    if state is not None:
        new_state = {"x_prev_c": x[:, -1].astype(jnp.float32)}
    return out, new_state
