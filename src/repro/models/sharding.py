"""Logical-axis sharding rules (MaxText-style) for every model family.

Logical axes:
  batch   activation batch            → (pod, data)
  fsdp    param non-contracting dim   → (pod, data)   (ZeRO-3 via GSPMD)
  tensor  heads / mlp / experts / vocab → model
  seq     long-context sequence dim   → data

`set_mesh(mesh)` installs a process-global mesh + rule map; model code calls
`constrain(x, ("batch", None, None))` and it becomes a no-op when no mesh is
installed (CPU unit tests) — so the same model code runs everywhere.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = {"mesh": None, "rules": None}


def default_rules(mesh: Mesh) -> dict:
    axes = mesh.axis_names
    dp = tuple(a for a in axes if a in ("pod", "data"))
    return {"batch": dp if dp else None,
            "fsdp": dp if dp else None,
            "tensor": "model" if "model" in axes else None,
            "seq": "data" if "data" in axes else None,
            # sequence parallelism over the *model* axis (§Perf seq_tp):
            "tp_seq": "model" if "model" in axes else None,
            None: None}


def set_mesh(mesh: Mesh | None, rules: dict | None = None):
    _STATE["mesh"] = mesh
    _STATE["rules"] = (rules or (default_rules(mesh) if mesh else None))


def get_mesh() -> Mesh | None:
    return _STATE["mesh"]


def logical_to_spec(axes: tuple | None) -> P:
    if axes is None:
        return P()
    rules = _STATE["rules"]
    return P(*(rules.get(a) for a in axes))


def constrain(x, axes: tuple | None):
    """with_sharding_constraint when a mesh is installed, else identity.
    Non-divisible dims fall back to replication (sanitize_spec)."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return x
    spec = sanitize_spec(mesh, logical_to_spec(axes), x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# parameter rules — keyed by leaf name (canonical [in, out] layouts)
# ---------------------------------------------------------------------------

F, T = "fsdp", "tensor"

PARAM_AXES = {
    # attention
    "wq": (F, T), "wk": (F, T), "wv": (F, T), "wo": (T, F),
    "bq": (T,), "bk": (T,), "bv": (T,),
    # dense FFN
    "w1": (F, T), "w3": (F, T), "w2": (T, F),
    # MoE (experts on tensor: expert parallelism)
    "router": (F, None),
    "moe_w1": (T, F, None), "moe_w3": (T, F, None), "moe_w2": (T, None, F),
    # embeddings
    "table": (T, F), "lm_head": (F, T),
    # rwkv
    "wg": (F, T), "wr": (F, T),
    "ck": (F, T), "cv": (T, F), "cr": (F, T),
    "wA": (F, None), "wB": (None, F), "u": (T, None),
    # griffin
    "w_gate": (F, T), "w_x": (F, T), "conv_w": (None, T), "conv_b": (T,),
    "w_r": (T, None), "w_i": (T, None), "lam": (T,), "w_out": (T, F),
}


def _leaf_axes(path, leaf) -> tuple | None:
    name = None
    for entry in reversed(path):
        if hasattr(entry, "key"):
            name = entry.key
            break
    axes = PARAM_AXES.get(name)
    if axes is None:
        return None  # replicate (norm scales, mus, w0, ln_x, …)
    extra = leaf.ndim - len(axes)
    if extra > 0:  # stacked scan segments prepend layer dims
        axes = (None,) * extra + tuple(axes)
    elif extra < 0:
        return None
    return axes


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for e in entry:
            n *= mesh.shape[e]
        return n
    return mesh.shape[entry]


def sanitize_spec(mesh, spec: P, shape) -> P:
    """Drop spec entries whose mesh-axis product does not divide the dim —
    the general fallback that makes every (arch × mesh) lower (e.g. granite
    vocab 49155 is odd → embed vocab dim replicates; decode batch 1 can't
    shard over dp).  Replication is always legal; GSPMD handles the rest."""
    ents = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    fixed = tuple(e if d % _axis_size(mesh, e) == 0 else None
                  for e, d in zip(ents, shape))
    return P(*fixed)


def param_specs(params) -> "jax.tree_util.PyTreeDef":
    """Tree of PartitionSpec matching `params` (works on ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: logical_to_spec(_leaf_axes(p, x)), params)


def param_shardings(mesh, params):
    return jax.tree_util.tree_map_with_path(
        lambda p, x: NamedSharding(
            mesh, sanitize_spec(mesh, logical_to_spec(_leaf_axes(p, x)),
                                x.shape)),
        params)


# ---------------------------------------------------------------------------
# cache / activation rules
# ---------------------------------------------------------------------------


def cache_axes(path, leaf, batch: int, dp_size: int) -> tuple:
    """KV caches: shard batch when divisible, else shard long seq dims."""
    name = None
    for entry in reversed(path):
        if hasattr(entry, "key"):
            name = entry.key
            break
    if name == "index" or leaf.ndim <= 1:
        return None
    shard_batch = batch % max(dp_size, 1) == 0 and batch >= dp_size
    if name in ("k", "v"):           # [n_rep, B, S, Hkv, hd]
        if shard_batch:
            return (None, "batch", None, None, None)
        return (None, None, "seq", None, None)
    if name == "wkv":                # [n_rep, B, H, K, V]
        return (None, "batch", "tensor", None, None) if shard_batch \
            else (None, None, "tensor", None, None)
    if name == "h":                  # [n_rep, B, W]
        return (None, "batch", "tensor") if shard_batch \
            else (None, None, "tensor")
    if name == "conv":               # [n_rep, B, K-1, W]
        return (None, "batch", None, "tensor") if shard_batch \
            else (None, None, None, "tensor")
    if name in ("x_prev_t", "x_prev_c"):  # [n_rep, B, D]
        return (None, "batch", None) if shard_batch else None
    return None


def cache_specs(cache, batch: int, dp_size: int):
    return jax.tree_util.tree_map_with_path(
        lambda p, x: logical_to_spec(cache_axes(p, x, batch, dp_size)), cache)
