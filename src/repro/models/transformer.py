"""Decoder assembly: segmented scan-over-layers, train loss, prefill/decode.

Layer stacks are grouped into segments of repeating units (cfg.segments) and
executed with `jax.lax.scan` over stacked parameters, so HLO size and compile
time are O(|pattern|), not O(depth) — 126-layer llama3-405b compiles as one
scanned unit.  Heterogeneous patterns (gemma3 5×local+1×global,
recurrentgemma rec,rec,attn) unroll the unit *inside* the scan body.

Cache pytree: {"index": int32 scalar, "segments": (per-segment stacked
per-layer state, leading dim = n_rep)}.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import sharding
from .attention import attention_mixer, attn_init, init_kv_cache
from .griffin import griffin_init, griffin_mixer, griffin_state_init
from .layers import embed, embed_init, ffn, ffn_init, norm, norm_init, unembed
from .moe import moe_ffn, moe_init
from .rwkv import (rwkv_channel_mix, rwkv_init, rwkv_state_init,
                   rwkv_time_mix)

# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def layer_init(key, cfg, kind: str):
    k1, k2 = jax.random.split(key)
    if kind == "rwkv":
        return {"norm1": norm_init(cfg), "norm2": norm_init(cfg),
                "rwkv": rwkv_init(k1, cfg)}
    if kind == "rec":
        mixer = {"rec": griffin_init(k1, cfg)}
    else:
        mixer = {"attn": attn_init(k1, cfg)}
    ffn_p = moe_init(k2, cfg) if cfg.is_moe else ffn_init(k2, cfg)
    return {"norm1": norm_init(cfg), "norm2": norm_init(cfg),
            **mixer, "ffn": ffn_p}


def unit_init(key, cfg, unit):
    keys = jax.random.split(key, len(unit))
    return {f"l{i}": layer_init(keys[i], cfg, kind)
            for i, kind in enumerate(unit)}


def init_params(cfg, key):
    keys = jax.random.split(key, len(cfg.segments) + 1)
    segs = {}
    for si, (unit, n_rep) in enumerate(cfg.segments):
        rep_keys = jax.random.split(keys[si], n_rep)
        segs[f"seg{si}"] = jax.vmap(lambda k: unit_init(k, cfg, unit))(rep_keys)
    return {"embed": embed_init(keys[-1], cfg),
            "segments": segs,
            "final_norm": norm_init(cfg)}


def abstract_params(cfg, key=None):
    """ShapeDtypeStruct pytree — no allocation (dry-run path)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: init_params(cfg, k), key)


# ---------------------------------------------------------------------------
# cache
# ---------------------------------------------------------------------------


def layer_cache_struct(cfg, kind, batch, max_len, dtype=jnp.bfloat16):
    if kind == "rwkv":
        return rwkv_state_init(cfg, batch)
    if kind == "rec":
        return griffin_state_init(cfg, batch)
    return init_kv_cache(cfg, kind, batch, max_len, dtype)


def init_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    segs = {}
    for si, (unit, n_rep) in enumerate(cfg.segments):
        unit_struct = {f"l{i}": layer_cache_struct(cfg, kind, batch, max_len,
                                                   dtype)
                       for i, kind in enumerate(unit)}
        segs[f"seg{si}"] = jax.tree.map(
            lambda x: jnp.zeros((n_rep,) + x.shape, x.dtype), unit_struct)
    return {"index": jnp.zeros((), jnp.int32), "segments": segs}


def abstract_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: init_cache(cfg, batch, max_len, dtype))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _apply_layer(lp, h, cfg, kind, positions, lcache, index):
    """One layer (pre-norm residual).  Returns (h, new_cache, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "rwkv":
        o, s1 = rwkv_time_mix(lp["rwkv"], norm(cfg, lp["norm1"], h), cfg,
                              lcache)
        h = h + o
        o, s2 = rwkv_channel_mix(lp["rwkv"], norm(cfg, lp["norm2"], h), cfg,
                                 lcache)
        h = h + o
        new_cache = {**s1, **s2} if lcache is not None else None
        return h, new_cache, aux
    if kind == "rec":
        o, s = griffin_mixer(lp["rec"], norm(cfg, lp["norm1"], h), cfg, lcache)
        h = h + o
        new_cache = s
    else:
        o, s = attention_mixer(lp["attn"], norm(cfg, lp["norm1"], h), cfg,
                               kind=kind, positions=positions, cache=lcache,
                               index=index)
        h = h + o
        new_cache = s
    hn = norm(cfg, lp["norm2"], h)
    if cfg.residual_shard == "seq" and cfg.sp_style == "megatron" \
            and hn.shape[1] > 1:
        # Megatron-SP: gather the tokens over the model axis here (one
        # bf16 all-gather) so the FFN weights stay TP-sharded; the residual
        # constraint after the block turns wo/w2 partial sums into
        # reduce-scatters.
        hn = sharding.constrain(hn, ("batch", None, None))
    if cfg.is_moe:
        o, aux = moe_ffn(lp["ffn"], hn, cfg)
    else:
        o = ffn(lp["ffn"], hn, cfg)
    return h + o, new_cache, aux


def _apply_unit(up, h, cfg, unit, positions, ucache, index):
    seq_ax = "tp_seq" if cfg.residual_shard == "seq" and h.shape[1] > 1 \
        else None
    h = sharding.constrain(h, ("batch", seq_ax, None))
    auxs = jnp.zeros((), jnp.float32)
    new_cache = {}
    for i, kind in enumerate(unit):
        lc = None if ucache is None else ucache[f"l{i}"]
        h, nc, aux = _apply_layer(up[f"l{i}"], h, cfg, kind, positions, lc,
                                  index)
        auxs += aux
        if ucache is not None:
            new_cache[f"l{i}"] = nc
    return h, (new_cache if ucache is not None else None), auxs


def _run_segments(params, h, cfg, positions, cache, index):
    new_segs = {}
    aux_total = jnp.zeros((), jnp.float32)
    for si, (unit, n_rep) in enumerate(cfg.segments):
        seg_params = params["segments"][f"seg{si}"]
        seg_cache = None if cache is None else cache["segments"][f"seg{si}"]

        def body(carry, xs, _unit=unit):
            hh, aux = carry
            up, uc = xs
            hh, nc, a = _apply_unit(up, hh, cfg, _unit, positions, uc, index)
            return (hh, aux + a), nc

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        # scan_unroll > 1 is used by the dry-run cost accounting (the XLA
        # cost model counts while bodies once; unroll-diff recovers ×n_rep).
        unroll = min(cfg.scan_unroll, n_rep) if n_rep > 1 else 1
        (h, aux_total), seg_new = jax.lax.scan(
            body, (h, aux_total), (seg_params, seg_cache), unroll=unroll)
        new_segs[f"seg{si}"] = seg_new
    return h, (new_segs if cache is not None else None), aux_total


def forward(params, inputs, cfg, *, positions=None, cache=None):
    """inputs: tokens [B, T] int (embed_inputs) or embeds [B, T, D].

    Returns (hidden [B, T, D], new_cache, aux_loss)."""
    if cfg.embed_inputs:
        h = embed(params["embed"], inputs, cfg)
        B, T = inputs.shape[:2]
    else:
        h = inputs.astype(cfg.act_dtype)
        B, T = inputs.shape[:2]

    index = cache["index"] if cache is not None else 0
    if positions is None:
        pos = jnp.arange(T)[None] + index
        positions = jnp.broadcast_to(pos, (B, T))

    h, new_segs, aux = _run_segments(params, h, cfg, positions, cache, index)
    h = norm(cfg, params["final_norm"], h)

    new_cache = None
    if cache is not None:
        new_cache = {"index": index + T, "segments": new_segs}
    return h, new_cache, aux


def logits_fn(params, h, cfg):
    logits = unembed(params["embed"], h, cfg)
    return sharding.constrain(logits, ("batch", None, "tensor"))


# ---------------------------------------------------------------------------
# losses / steps
# ---------------------------------------------------------------------------


def chunked_xent(params, h, labels, mask, cfg, chunk: int = 512):
    """Cross-entropy without materialising [B, T, V] logits: scan over T
    chunks (peak memory chunk×V — essential at vocab 256k × 1M tokens)."""
    B, T, D = h.shape
    pt = (-T) % chunk
    if pt:
        h = jnp.pad(h, ((0, 0), (0, pt), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pt)))
        mask = jnp.pad(mask, ((0, 0), (0, pt)))
    nC = (T + pt) // chunk
    hc = h.reshape(B, nC, chunk, D).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, nC, chunk).transpose(1, 0, 2)
    mc = mask.reshape(B, nC, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        tot, cnt = carry
        hh, ll, mm = xs
        logits = logits_fn(params, hh, cfg).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mm
        return (tot + jnp.sum(nll), cnt + jnp.sum(mm)), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(params, batch, cfg, xent_chunk: int = 512):
    """batch: {"tokens" or "embeds", "labels", optional "mask", "positions"}."""
    inputs = batch["tokens"] if cfg.embed_inputs else batch["embeds"]
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones(labels.shape, jnp.float32))
    h, _, aux = forward(params, inputs, cfg,
                        positions=batch.get("positions"))
    loss = chunked_xent(params, h, labels, mask, cfg, chunk=xent_chunk)
    return loss + aux, {"xent": loss, "aux": aux}


def prefill(params, inputs, cfg, cache, positions=None):
    """Run the prompt, fill the cache, return last-token hidden state."""
    h, new_cache, _ = forward(params, inputs, cfg, positions=positions,
                              cache=cache)
    return h[:, -1:], new_cache


def decode_step(params, inputs, cfg, cache, positions=None):
    """One token per sequence.  inputs: [B, 1] tokens (or [B, 1, D] embeds)."""
    h, new_cache, _ = forward(params, inputs, cfg, positions=positions,
                              cache=cache)
    logits = logits_fn(params, h, cfg)
    return logits, new_cache
