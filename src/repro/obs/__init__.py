"""Runtime telemetry: span tracing, metrics, kernel-dispatch profiling.

Three cooperating modules, all near-zero-cost until switched on:

  `trace`           ring-buffer span tracer → Chrome-trace/Perfetto JSON
                    (``REPRO_TRACE=1``, ``REPRO_TRACE_PATH=...``)
  `metrics`         counters / gauges / log-bucketed histograms, JSON
                    snapshot + Prometheus text exposition
  `kernel_profile`  per-dispatch records behind `kernels/ops.py`: op,
                    impl, shape key, analytic bytes moved, compile vs
                    steady wall time (``REPRO_KERNEL_PROFILE=1`` or the
                    trace gate)

Consumers: `serving.engine.ServeEngine.metrics_snapshot()`,
`training.train_loop.train(metrics=, monitor=)`, and
``python -m repro.analysis.report --metrics <snapshot.json>``.
"""

from . import kernel_profile, metrics, trace  # noqa: F401

__all__ = ["trace", "metrics", "kernel_profile"]
