"""Kernel-dispatch profiling: per-op records behind `kernels/ops.py`.

Every dispatch through the unified ops surface (`conv2d` / `attention` /
`log_matmul` / `wkv6`) is recorded here when profiling is on: the op, the
resolved impl, the shape key (the same namespaced key the autotuner
uses), the **analytic bytes moved** (from `conv_traffic_bytes` /
`attention_traffic_bytes` — the paper's per-layer traffic accounting),
and wall time split into first-call (compile-inclusive) vs steady state,
measured around `jax.block_until_ready`.

Two dispatch regimes:

  eager    the op ran on concrete arrays — it is timed directly; the
           first call for a key is the compile-inclusive sample, later
           calls accumulate steady-state stats.
  traced   the op ran on tracers inside a `jax.jit` trace — there is no
           per-op wall clock (XLA fuses the program), so the record
           carries shape/bytes only and is tagged with the enclosing
           **program** (`time_program`, e.g. the serving engine's
           "prefill"/"decode" jit calls).  `snapshot()` then attributes
           the program's measured steady time to its kernel records, so
           per-op rows always carry a defensible steady-µs figure.

Gating mirrors the tracer: ``REPRO_KERNEL_PROFILE=1`` or ``REPRO_TRACE=1``
(a trace without kernel rows is half a trace), or `set_enabled(True)`.
Disabled cost is one env check per op call; crucially, the
`block_until_ready` sync — which would break async dispatch pipelining —
only ever happens while profiling is on.
"""

from __future__ import annotations

import os
import threading
import time

import jax

from . import metrics as _metrics
from . import trace as _trace

_OFF = ("", "0", "false", "off")


def is_traced(*operands) -> bool:
    """True when any operand is a JAX tracer (op is being staged, not run)."""
    return any(isinstance(x, jax.core.Tracer) for x in operands)


def _new_entry(op, impl, key, bytes_moved):
    return {"op": op, "impl": impl, "key": key, "bytes": bytes_moved,
            "calls": 0, "traced_calls": 0, "first_us": None,
            "steady_n": 0, "steady_sum": 0.0, "steady_min": None,
            "program": None}


def _push_steady(ent, dt_us):
    ent["steady_n"] += 1
    ent["steady_sum"] += dt_us
    ent["steady_min"] = dt_us if ent["steady_min"] is None \
        else min(ent["steady_min"], dt_us)


class KernelProfiler:
    """Process-wide dispatch recorder used by `kernels/ops.py`."""

    def __init__(self):
        self._lock = threading.Lock()
        self._entries: dict[tuple, dict] = {}
        self._programs: dict[str, dict] = {}
        self._local = threading.local()
        self._override: bool | None = None

    # ------------------------------------------------------------- gating
    def enabled(self) -> bool:
        if self._override is not None:
            return self._override
        if os.environ.get("REPRO_KERNEL_PROFILE", "0").lower() not in _OFF:
            return True
        return _trace.TRACER.enabled()

    def set_enabled(self, flag: bool | None) -> None:
        """True/False force; None defers to the env gates."""
        self._override = flag

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._programs.clear()

    # ----------------------------------------------------------- programs
    def current_program(self) -> str | None:
        return getattr(self._local, "program", None)

    def time_program(self, name: str, fn):
        """Run `fn` (typically one jitted engine program) under a named
        program scope: traced kernel dispatches inside it are tagged with
        `name`, and the call is timed end-to-end via `block_until_ready`
        (first call = compile-inclusive, later calls = steady)."""
        if not self.enabled():
            return fn()
        prev = getattr(self._local, "program", None)
        self._local.program = name
        t0 = time.perf_counter_ns()
        try:
            out = fn()
        finally:
            self._local.program = prev
        jax.block_until_ready(out)
        dt_ns = time.perf_counter_ns() - t0
        dt_us = dt_ns / 1e3
        with self._lock:
            ent = self._programs.setdefault(
                name, {"calls": 0, "first_us": None, "steady_n": 0,
                       "steady_sum": 0.0, "steady_min": None})
            first = ent["calls"] == 0
            if first:
                ent["first_us"] = dt_us
            else:
                _push_steady(ent, dt_us)
            ent["calls"] += 1
        _trace.TRACER.add_complete(name, t0, dt_ns,
                                   phase="compile" if first else "steady")
        return out

    # ----------------------------------------------------------- dispatch
    def dispatch(self, op: str, impl: str, key: str, bytes_moved: dict,
                 fn, *, traced: bool):
        """The hook `kernels/ops.py` routes every kernel call through."""
        if not self.enabled():
            return fn()
        if traced:
            with self._lock:
                ent = self._entries.setdefault(
                    (op, impl, key), _new_entry(op, impl, key, bytes_moved))
                ent["traced_calls"] += 1
                prog = self.current_program()
                if prog is not None:
                    ent["program"] = prog
            _trace.TRACER.instant(f"trace:{op}[{impl}]", key=key)
            return fn()
        t0 = time.perf_counter_ns()
        out = fn()
        jax.block_until_ready(out)
        dt_ns = time.perf_counter_ns() - t0
        dt_us = dt_ns / 1e3
        with self._lock:
            ent = self._entries.setdefault(
                (op, impl, key), _new_entry(op, impl, key, bytes_moved))
            first = ent["calls"] == 0
            if first:
                ent["first_us"] = dt_us
            else:
                _push_steady(ent, dt_us)
            ent["calls"] += 1
        phase = "compile" if first else "steady"
        _trace.TRACER.add_complete(f"{op}[{impl}]", t0, dt_ns,
                                   key=key, phase=phase)
        _metrics.REGISTRY.histogram("kernel_dispatch_us",
                                    bounds=_metrics.US_BUCKETS,
                                    op=op, impl=impl,
                                    phase=phase).record(dt_us)
        return out

    # ------------------------------------------------------------ readout
    def snapshot(self) -> dict:
        """{"records": [per-(op, impl, key) rows], "programs": {...}}.

        Rows always carry `steady_us` when any steady sample exists:
        eagerly-timed ops report their own mean, traced ops inherit their
        program's steady mean (`steady_source` says which)."""
        with self._lock:
            entries = [dict(e) for e in self._entries.values()]
            programs = {n: dict(p) for n, p in self._programs.items()}
        for p in programs.values():
            p["steady_us"] = (p["steady_sum"] / p["steady_n"]
                              if p["steady_n"] else None)
            del p["steady_sum"]
        records = []
        for e in entries:
            r = {k: e[k] for k in ("op", "impl", "key", "bytes", "calls",
                                   "traced_calls", "first_us", "program")}
            if e["steady_n"]:
                r["steady_us"] = e["steady_sum"] / e["steady_n"]
                r["steady_us_min"] = e["steady_min"]
                r["steady_source"] = "self"
            else:
                prog = programs.get(e["program"]) or {}
                r["steady_us"] = prog.get("steady_us") or prog.get("first_us")
                r["steady_us_min"] = prog.get("steady_min")
                r["steady_source"] = (f"program:{e['program']}"
                                      if r["steady_us"] is not None else None)
            records.append(r)
        return {"records": records, "programs": programs}


PROFILER = KernelProfiler()

dispatch = PROFILER.dispatch
time_program = PROFILER.time_program
snapshot = PROFILER.snapshot
set_enabled = PROFILER.set_enabled
enabled = PROFILER.enabled
clear = PROFILER.clear
