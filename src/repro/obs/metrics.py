"""Metrics registry: counters, gauges, log-bucketed histograms.

The live counterpart of the offline ``BENCH_*.json`` artifacts: the same
quantities the paper reports per layer (latency, throughput, traffic) as
continuously-updated process metrics.  Three instrument kinds:

  Counter    monotonically increasing (requests served, autotune misses)
  Gauge      last-write-wins level (queue depth, busy slots)
  Histogram  fixed **log-spaced** buckets — latencies span orders of
             magnitude, so geometric buckets give constant relative error
             for percentile estimates at O(#buckets) memory.

Instruments are get-or-create by ``(name, labels)`` so call sites never
coordinate.  Snapshots are plain JSON-able dicts; `to_prometheus()` emits
the standard text exposition (cumulative ``_bucket{le=...}`` series) for
scrape-based collection.

A process-wide default registry (`REGISTRY`) serves cross-cutting
producers (kernel dispatch, autotune hit/miss); components that need
isolation (one `ServeEngine` per test) build their own instance.
"""

from __future__ import annotations

import bisect
import json
import threading


def log_bucket_bounds(lo: float = 1e-5, hi: float = 100.0,
                      per_decade: int = 5) -> tuple[float, ...]:
    """Geometric bucket upper bounds covering [lo, hi] with `per_decade`
    buckets per decade (an overflow bucket is implicit past the last)."""
    if not (0 < lo < hi):
        raise ValueError(f"need 0 < lo < hi, got {lo}, {hi}")
    bounds, i = [], 0
    while True:
        b = lo * 10.0 ** (i / per_decade)
        bounds.append(b)
        if b >= hi:
            return tuple(bounds)
        i += 1


# seconds-scale latencies: 10 µs … 100 s
DEFAULT_TIME_BUCKETS = log_bucket_bounds(1e-5, 100.0, per_decade=5)
# µs-scale kernel dispatch times: 1 µs … 10 s
US_BUCKETS = log_bucket_bounds(1.0, 1e7, per_decade=4)
# rates (tokens/s etc.): 0.1 … 1e6
RATE_BUCKETS = log_bucket_bounds(0.1, 1e6, per_decade=4)


def _label_suffix(labels: tuple) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in labels) + "}"


class Counter:
    __slots__ = ("name", "labels", "_v", "_lock")

    def __init__(self, name: str, labels: tuple):
        self.name, self.labels = name, labels
        self._v = 0
        self._lock = threading.Lock()

    def inc(self, n: int | float = 1):
        with self._lock:
            self._v += n

    @property
    def value(self):
        return self._v


class Gauge:
    __slots__ = ("name", "labels", "_v", "_lock")

    def __init__(self, name: str, labels: tuple):
        self.name, self.labels = name, labels
        self._v = 0.0
        self._lock = threading.Lock()

    def set(self, v: float):
        with self._lock:
            self._v = v

    def inc(self, n: float = 1):
        with self._lock:
            self._v += n

    @property
    def value(self):
        return self._v


class Histogram:
    """Fixed-bound histogram; `bounds` are ascending bucket upper edges,
    with one implicit overflow bucket past the last."""
    __slots__ = ("name", "labels", "bounds", "_counts", "_sum", "_count",
                 "_min", "_max", "_lock")

    def __init__(self, name: str, labels: tuple,
                 bounds: tuple = DEFAULT_TIME_BUCKETS):
        self.name, self.labels = name, labels
        self.bounds = tuple(bounds)
        self._counts = [0] * (len(self.bounds) + 1)
        self._sum = 0.0
        self._count = 0
        self._min = None
        self._max = None
        self._lock = threading.Lock()

    def record(self, v: float):
        idx = bisect.bisect_left(self.bounds, v)
        with self._lock:
            self._counts[idx] += 1
            self._sum += v
            self._count += 1
            self._min = v if self._min is None else min(self._min, v)
            self._max = v if self._max is None else max(self._max, v)

    @property
    def count(self):
        return self._count

    @property
    def sum(self):
        return self._sum

    @property
    def mean(self):
        return self._sum / self._count if self._count else 0.0

    def percentile(self, p: float) -> float:
        """Bucket-resolution quantile (p in [0, 100]): the geometric
        midpoint of the bucket holding the p-th sample, clamped to the
        observed min/max so tails stay honest."""
        with self._lock:
            total = self._count
            if not total:
                return 0.0
            target = max(1, -(-total * p // 100))  # ceil
            cum = 0
            for i, c in enumerate(self._counts):
                cum += c
                if cum >= target:
                    if i >= len(self.bounds):       # overflow bucket
                        est = self._max
                    else:
                        hi = self.bounds[i]
                        lo = self.bounds[i - 1] if i else hi / 10.0
                        est = (lo * hi) ** 0.5
                    return min(max(est, self._min), self._max)
            return self._max  # pragma: no cover

    def snapshot(self) -> dict:
        with self._lock:
            return {"count": self._count, "sum": self._sum,
                    "min": self._min, "max": self._max,
                    "mean": self.mean,
                    "buckets": [[b, c] for b, c
                                in zip(self.bounds, self._counts)]
                    + [["+Inf", self._counts[-1]]]} | {
                        f"p{p}": self._percentile_unlocked(p)
                        for p in (50, 90, 99)}

    def _percentile_unlocked(self, p):
        # snapshot() holds the lock; percentile() re-acquires — compute on
        # the already-consistent state instead.
        total = self._count
        if not total:
            return 0.0
        target = max(1, -(-total * p // 100))
        cum = 0
        for i, c in enumerate(self._counts):
            cum += c
            if cum >= target:
                if i >= len(self.bounds):
                    est = self._max
                else:
                    hi = self.bounds[i]
                    lo = self.bounds[i - 1] if i else hi / 10.0
                    est = (lo * hi) ** 0.5
                return min(max(est, self._min), self._max)
        return self._max  # pragma: no cover


class MetricsRegistry:
    """Get-or-create instrument store, snapshot- and Prometheus-exportable."""

    def __init__(self):
        self._metrics: dict = {}
        self._lock = threading.Lock()

    def _get(self, cls, name, labels, **kw):
        key = (name, tuple(sorted(labels.items())))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, key[1], **kw)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, bounds: tuple = DEFAULT_TIME_BUCKETS,
                  **labels) -> Histogram:
        return self._get(Histogram, name, labels, bounds=bounds)

    def clear(self) -> None:
        with self._lock:
            self._metrics.clear()

    # -------------------------------------------------------------- export
    def snapshot(self) -> dict:
        """JSON-able state: {"counters": {...}, "gauges": {...},
        "histograms": {full_name: {count, sum, mean, p50, ...}}}."""
        with self._lock:
            metrics = list(self._metrics.values())
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for m in metrics:
            full = m.name + _label_suffix(m.labels)
            if isinstance(m, Counter):
                out["counters"][full] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][full] = m.value
            else:
                out["histograms"][full] = m.snapshot()
        return out

    def dump_json(self, path: str) -> dict:
        snap = self.snapshot()
        with open(path, "w") as f:
            json.dump(snap, f, indent=1, sort_keys=True, default=str)
            f.write("\n")
        return snap

    def to_prometheus(self) -> str:
        """Prometheus text exposition (histograms as cumulative buckets)."""
        with self._lock:
            metrics = list(self._metrics.values())
        lines, typed = [], set()
        for m in sorted(metrics, key=lambda m: m.name):
            kind = {Counter: "counter", Gauge: "gauge"}.get(
                type(m), "histogram")
            if m.name not in typed:
                lines.append(f"# TYPE {m.name} {kind}")
                typed.add(m.name)
            suffix = _label_suffix(m.labels)
            if kind in ("counter", "gauge"):
                lines.append(f"{m.name}{suffix} {m.value}")
                continue
            cum = 0
            base = dict(m.labels)
            for b, c in zip(m.bounds, m._counts):
                cum += c
                lab = _label_suffix(tuple(sorted(
                    {**base, "le": repr(b)}.items())))
                lines.append(f"{m.name}_bucket{lab} {cum}")
            lab = _label_suffix(tuple(sorted(
                {**base, "le": "+Inf"}.items())))
            lines.append(f"{m.name}_bucket{lab} {m.count}")
            lines.append(f"{m.name}_sum{suffix} {m.sum}")
            lines.append(f"{m.name}_count{suffix} {m.count}")
        return "\n".join(lines) + ("\n" if lines else "")


REGISTRY = MetricsRegistry()
