"""Low-overhead span tracer: ring buffer → Chrome-trace/Perfetto JSON.

The paper argues NeuroMAX entirely through measurement (per-layer latency
and utilization, §V); this module is the live-measurement half of that
story — every span is a `(name, t0, dur, tid, args)` tuple in a bounded
thread-safe ring buffer, exported in the Chrome ``traceEvents`` format
that both ``chrome://tracing`` and Perfetto load directly.

Gating: tracing is OFF unless ``REPRO_TRACE=1`` is set (or
`set_enabled(True)` is called programmatically — `set_enabled(None)`
defers back to the env).  When disabled, `span()` returns one shared
no-op context manager and `instant()` returns immediately, so the cost
on a hot path is a single attribute load + env check (~100 ns) — cheap
enough to leave call sites unconditional.

    from repro.obs import trace
    with trace.span("prefill", uid=3):
        ...
    trace.export_chrome_trace("trace.json")

``REPRO_TRACE_PATH=/path.json`` additionally auto-exports the buffer at
interpreter exit, so any driver run under ``REPRO_TRACE=1`` leaves a
loadable trace behind without code changes.
"""

from __future__ import annotations

import atexit
import json
import os
import threading
import time
from collections import deque
from functools import wraps

_DEFAULT_CAPACITY = 65536
_OFF = ("", "0", "false", "off")


class _NullSpan:
    """Shared no-op span handed out while tracing is disabled."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **args):
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tracer", "name", "args", "_t0")

    def __init__(self, tracer, name, args):
        self._tracer, self.name, self.args = tracer, name, args
        self._t0 = 0

    def set(self, **args):
        """Attach attributes mid-span (rendered under `args` in the UI)."""
        self.args.update(args)
        return self

    def __enter__(self):
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        self._tracer._push(("X", self.name, self._t0, t1 - self._t0,
                            threading.get_ident(), self.args or None))
        return False


class Tracer:
    """Thread-safe bounded span buffer with Chrome-trace export."""

    def __init__(self, capacity: int = _DEFAULT_CAPACITY):
        self._buf: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._override: bool | None = None

    # ------------------------------------------------------------- gating
    def enabled(self) -> bool:
        if self._override is not None:
            return self._override
        return os.environ.get("REPRO_TRACE", "0").lower() not in _OFF

    def set_enabled(self, flag: bool | None) -> None:
        """True/False force; None defers to ``$REPRO_TRACE``."""
        self._override = flag

    # ----------------------------------------------------------- recording
    def span(self, name: str, **args):
        """Context manager timing a block; no-op (shared object) when off."""
        if not self.enabled():
            return _NULL_SPAN
        return _Span(self, name, args)

    def instant(self, name: str, **args) -> None:
        """Zero-duration marker event."""
        if not self.enabled():
            return
        self._push(("i", name, time.perf_counter_ns(), 0,
                    threading.get_ident(), args or None))

    def add_complete(self, name: str, t0_ns: int, dur_ns: int, **args):
        """Record an externally-timed span (e.g. a `block_until_ready`-timed
        jit call whose clock the caller already owns)."""
        if not self.enabled():
            return
        self._push(("X", name, t0_ns, dur_ns, threading.get_ident(),
                    args or None))

    def _push(self, ev: tuple) -> None:
        with self._lock:
            self._buf.append(ev)

    # ------------------------------------------------------------- readout
    def events(self) -> list:
        with self._lock:
            return list(self._buf)

    def clear(self) -> None:
        with self._lock:
            self._buf.clear()

    def chrome_events(self) -> list[dict]:
        pid = os.getpid()
        out = []
        for ph, name, ts, dur, tid, args in self.events():
            ev = {"ph": ph, "name": name, "cat": "repro",
                  "ts": ts / 1e3, "pid": pid, "tid": tid}
            if ph == "X":
                ev["dur"] = dur / 1e3
            elif ph == "i":
                ev["s"] = "t"
            if args:
                ev["args"] = args
            out.append(ev)
        return out

    def export_chrome_trace(self, path: str | None = None) -> dict:
        """Chrome ``traceEvents`` payload; written to `path` when given."""
        payload = {"traceEvents": self.chrome_events(),
                   "displayTimeUnit": "ms"}
        if path:
            d = os.path.dirname(path)
            if d:
                os.makedirs(d, exist_ok=True)
            with open(path, "w") as f:
                json.dump(payload, f, default=str)
                f.write("\n")
        return payload


TRACER = Tracer()

# module-level conveniences bound to the process-wide tracer
span = TRACER.span
instant = TRACER.instant
add_complete = TRACER.add_complete
enabled = TRACER.enabled
set_enabled = TRACER.set_enabled
events = TRACER.events
clear = TRACER.clear
export_chrome_trace = TRACER.export_chrome_trace


def traced(name: str | None = None, **static_args):
    """Decorator form: ``@traced()`` spans every call of the function."""
    def deco(fn):
        label = name or fn.__qualname__

        @wraps(fn)
        def wrapper(*a, **kw):
            if not TRACER.enabled():
                return fn(*a, **kw)
            with TRACER.span(label, **static_args):
                return fn(*a, **kw)
        return wrapper
    return deco


@atexit.register
def _export_at_exit():  # pragma: no cover - exercised via subprocess runs
    path = os.environ.get("REPRO_TRACE_PATH")
    if path and TRACER.events():
        try:
            TRACER.export_chrome_trace(path)
        except OSError:
            pass
