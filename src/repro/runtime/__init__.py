from .checkpoint import (CheckpointManager, load_checkpoint,  # noqa: F401
                         save_checkpoint)
from .monitor import (HeartbeatMonitor, RestartPolicy,        # noqa: F401
                      StragglerReport)
