"""Sharded, async, reshardable checkpointing.

Format (one directory per step):
    step_000123/
      manifest.json      step, flat param paths, shapes, dtypes, shard grid
      <path>.shard_i_of_n.npy     one file per (leaf, host-shard)

Properties needed at 1000+ nodes:
  · each host writes only the shards it owns (here: single-process writes
    all, but the shard loop is keyed by `jax.process_index()` so the same
    code runs multi-host);
  · writes are async (background thread) and atomic (tmp dir + rename), so
    a node failure mid-save never corrupts the latest checkpoint;
  · restore *reshards*: the manifest stores the logical array, not the mesh,
    so a checkpoint saved on 512 chips restores onto 8 — or onto a different
    (data, model) split — by assembling the logical array and re-slicing
    with the new sharding (elastic scaling);
  · `keep` rotation bounds disk; `latest_step()` enables blind restart.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------------ pytree io
def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(e, "key", getattr(e, "idx", e))) for e in path)
        flat[key] = leaf
    return flat


def _unflatten_into(template, flat: dict):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    vals = []
    for path, leaf in leaves:
        key = "/".join(
            str(getattr(e, "key", getattr(e, "idx", e))) for e in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        vals.append(flat[key])
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), vals)


# ------------------------------------------------------------------ save
def _shard_count(leaf) -> int:
    """Split big leaves across several files (parallel IO, resumable)."""
    return max(1, min(16, leaf.size * leaf.dtype.itemsize // (64 << 20)))


def save_checkpoint(directory: str, step: int, state, *, sync: bool = True):
    """Write `state` (pytree of arrays) at `step`.  Returns the final path."""
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(state)
    manifest = {"step": step, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        n = _shard_count(arr)
        manifest["leaves"][key] = {
            "shape": list(arr.shape), "dtype": str(arr.dtype), "shards": n}
        if arr.dtype.kind == "V":  # ml_dtypes (bfloat16, fp8): bit-cast for
            arr = arr.view(f"u{arr.dtype.itemsize}")  # portable .npy storage
        fname = key.replace("/", "__")
        if n == 1:
            np.save(os.path.join(tmp, f"{fname}.shard_0_of_1.npy"), arr)
        else:
            for i, piece in enumerate(np.array_split(arr.reshape(-1), n)):
                np.save(os.path.join(tmp, f"{fname}.shard_{i}_of_{n}.npy"),
                        piece)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


# ------------------------------------------------------------------ load
def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def load_checkpoint(directory: str, template, *, step: int | None = None,
                    shardings=None):
    """Restore into the structure of `template` (arrays or
    ShapeDtypeStructs).  `shardings`: optional pytree of NamedSharding — the
    *new* mesh layout; leaves are placed with jax.device_put so a checkpoint
    written under any old mesh reshards onto the current one."""
    step = latest_step(directory) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    flat_tpl = _flatten(template)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    flat = {}
    for key, meta in manifest["leaves"].items():
        if key not in flat_tpl:
            continue  # allow template subsets (e.g. params-only restore)
        n = meta["shards"]
        fname = key.replace("/", "__")
        if n == 1:
            arr = np.load(os.path.join(path, f"{fname}.shard_0_of_1.npy"))
        else:
            parts = [np.load(os.path.join(
                path, f"{fname}.shard_{i}_of_{n}.npy")) for i in range(n)]
            arr = np.concatenate(parts).reshape(meta["shape"])
        saved_dtype = np.dtype(meta["dtype"])
        if saved_dtype.kind == "V":    # undo the bit-cast of ml_dtypes
            arr = arr.view(saved_dtype)
        tpl = flat_tpl[key]
        if tuple(arr.shape) != tuple(tpl.shape):
            raise ValueError(f"{key}: ckpt shape {arr.shape} != "
                             f"template {tpl.shape}")
        if arr.dtype != tpl.dtype:
            arr = np.asarray(jnp.asarray(arr).astype(tpl.dtype))
        sh = flat_shard.get(key)
        flat[key] = jax.device_put(arr, sh) if sh is not None \
            else jnp.asarray(arr)
    for key in flat_tpl:
        if key not in flat:
            raise KeyError(f"checkpoint {path} missing leaf {key!r}")
    return _unflatten_into(template, flat), manifest["step"]


# ------------------------------------------------------------------ manager
class CheckpointManager:
    """Async save + rotation.  `save()` returns immediately; the previous
    async save is joined first (never two writers)."""

    def __init__(self, directory: str, *, keep: int = 3, every: int = 0):
        self.directory = directory
        self.keep = keep
        self.every = every
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def _rotate(self):
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, state, *, sync: bool = False):
        self.wait()
        # device_get on the caller thread (consistent snapshot), IO async
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)

        def work():
            save_checkpoint(self.directory, step, host_state)
            self._rotate()

        if sync:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def hook(self, every: int | None = None):
        """A train-loop hook: saves whenever step % every == 0."""
        every = every or self.every or 100

        def _hook(step, state, metrics):
            if step and step % every == 0:
                self.save(step, state)
        return _hook

    def restore(self, template, *, shardings=None, step=None):
        self.wait()
        return load_checkpoint(self.directory, template, step=step,
                               shardings=shardings)

    def latest_step(self):
        return latest_step(self.directory)
