"""Fleet health: heartbeats, straggler detection, restart policy.

At 1000+ nodes the failure model is: (a) hard node loss → detected by
missed heartbeats, handled by restart-from-checkpoint on a shrunken mesh
(checkpoint.py reshards); (b) stragglers (slow HBM, thermal throttle,
flaky ICI) → detected by per-step-time outliers, handled by exclusion
lists fed back to the scheduler.

This module is deliberately transport-agnostic: heartbeats are
`record(host, step, step_time)` calls; in a real deployment they arrive
over the coordination service (or jax.experimental.multihost_utils); in
tests they are driven synthetically.  The *logic* — windows, MAD-based
outlier detection, restart budgets — is the part worth testing and is
identical at any scale.
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque


@dataclasses.dataclass
class StragglerReport:
    step: int
    median_s: float
    threshold_s: float
    stragglers: dict          # host -> last step_time
    missing: list             # hosts with no heartbeat in the window


class HeartbeatMonitor:
    """Sliding-window heartbeat + straggler tracker."""

    def __init__(self, hosts: list, *, window: int = 8,
                 mad_factor: float = 5.0, miss_timeout_s: float = 60.0,
                 clock=time.monotonic):
        self.hosts = list(hosts)
        self.window = window
        self.mad_factor = mad_factor
        self.miss_timeout_s = miss_timeout_s
        self._clock = clock
        self._times = defaultdict(lambda: deque(maxlen=window))
        self._last_seen = {h: None for h in self.hosts}

    def record(self, host, step: int, step_time_s: float):
        if host not in self._last_seen:
            self.hosts.append(host)            # elastic scale-up
        self._times[host].append(step_time_s)
        self._last_seen[host] = (self._clock(), step)

    def _median(self, xs):
        s = sorted(xs)
        n = len(s)
        return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])

    def report(self, step: int) -> StragglerReport:
        now = self._clock()
        latest = {h: (self._times[h][-1] if self._times[h] else None)
                  for h in self.hosts}
        live = [v for v in latest.values() if v is not None]
        med = self._median(live) if live else 0.0
        mad = self._median([abs(v - med) for v in live]) if live else 0.0
        thr = med + self.mad_factor * max(mad, 0.05 * med, 1e-6)
        stragglers = {h: v for h, v in latest.items()
                      if v is not None and v > thr}
        missing = [h for h in self.hosts
                   if self._last_seen.get(h) is None
                   or now - self._last_seen[h][0] > self.miss_timeout_s]
        return StragglerReport(step=step, median_s=med, threshold_s=thr,
                               stragglers=stragglers, missing=missing)


@dataclasses.dataclass
class RestartPolicy:
    """Decides what to do after a failure report.

    budget: max restarts within `budget_window_s` before escalating to
    `abort` (a crash loop must not burn the whole allocation)."""

    budget: int = 5
    budget_window_s: float = 3600.0
    min_hosts_fraction: float = 0.5
    clock: object = time.monotonic

    def __post_init__(self):
        self._restarts: deque = deque()

    def decide(self, report: StragglerReport, n_hosts_total: int) -> dict:
        now = self.clock()
        while self._restarts and now - self._restarts[0] \
                > self.budget_window_s:
            self._restarts.popleft()

        n_lost = len(report.missing)
        healthy = n_hosts_total - n_lost
        if n_lost == 0:
            if report.stragglers:
                return {"action": "exclude",
                        "hosts": sorted(report.stragglers)}
            return {"action": "continue"}
        if healthy < self.min_hosts_fraction * n_hosts_total:
            return {"action": "abort",
                    "reason": f"only {healthy}/{n_hosts_total} hosts left"}
        if len(self._restarts) >= self.budget:
            return {"action": "abort", "reason": "restart budget exhausted"}
        self._restarts.append(now)
        # a restart must also shed the stragglers seen in the same report,
        # or the reshard lands the job right back on the slow hosts
        exclude = sorted(set(report.missing) | set(report.stragglers))
        return {"action": "restart",
                "exclude": exclude,
                "new_world": healthy,
                "note": "restore latest checkpoint, reshard onto "
                        f"{healthy} hosts"}
