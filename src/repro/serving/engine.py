"""Batched serving engine: continuous-batching prefill + decode over the
model zoo's unified cache pytree (KV caches for attention layers, recurrent
state for RWKV/RG-LRU layers — `transformer.init_cache` covers all three).

Design (vLLM-style, adapted to JAX static shapes):
  · fixed engine batch of `max_batch` slots, each slot = one sequence;
  · **prefill** runs one slot at a time at its own prompt length.  For
    attention-only archs prompts are right-padded to a power-of-two bucket
    (pad keys land at positions > index and are causally masked, then
    progressively overwritten during decode, so they are never visible);
    archs with recurrent layers (rwkv/rec) use exact lengths — any padding
    would pollute the recurrent state;
  · **decode** is one jitted program for all slots, vmapped over the slot
    axis so every slot carries its own absolute position (ragged batching
    without recompiles);
  · finished slots are refilled from the queue between decode steps
    (continuous batching) — shapes never change;
  · log-quantized weights (cfg.quant == "logq6") cut weight HBM traffic
    2.67× — the dominant roofline term of decode (§Roofline).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # [T] int32
    max_new_tokens: int = 16
    temperature: float = 0.0           # 0 → greedy
    seed: int = 0
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 8
    max_prompt: int = 128
    max_len: int = 256                 # cache capacity (prompt + generation)
    eos_id: int = -1                   # -1: never stop on a token
    cache_dtype: Any = jnp.float32
    # override the model's attention dispatch for serving (None: keep the
    # model config's attn_impl).  Decode positions are traced scalars —
    # the Pallas kernel takes them as scalar-prefetch operands, so
    # "pallas" is a valid serving impl, not just "blockwise"/"ref".
    attn_impl: str | None = None


def _has_recurrence(cfg) -> bool:
    return any(k in ("rwkv", "rec") for k in cfg.layer_pattern)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class ServeEngine:
    def __init__(self, cfg, params, ecfg: EngineConfig = EngineConfig()):
        if not cfg.embed_inputs:
            raise ValueError("engine serves token archs; frontend-stub archs "
                             "(musicgen) are driven via launch/serve.py "
                             "embeddings path")
        if ecfg.attn_impl is not None:
            cfg = dataclasses.replace(cfg, attn_impl=ecfg.attn_impl)
        self.cfg, self.params, self.ecfg = cfg, params, ecfg
        B, L = ecfg.max_batch, ecfg.max_len
        self.cache = transformer.init_cache(cfg, B, L, ecfg.cache_dtype)
        self._pad_prefill = not _has_recurrence(cfg)
        # per-slot host state
        self.slot_req: list[Request | None] = [None] * B
        self.slot_pos = np.zeros(B, np.int32)      # next write position
        self.slot_last = np.zeros(B, np.int32)     # last emitted token
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.stats = {"prefill_calls": 0, "decode_steps": 0,
                      "tokens_out": 0}

        cfg_ = cfg

        def _prefill(params, seg_slot, tokens, length):
            """One slot.  seg_slot: cache segments sliced to B=1 and zeroed.
            tokens: [1, Tpad]; length: real length (static via bucket)."""
            cache = {"index": jnp.zeros((), jnp.int32), "segments": seg_slot}
            h, new_cache, _ = transformer.forward(
                params, tokens, cfg_, cache=cache)
            last = jax.lax.dynamic_slice_in_dim(h, length - 1, 1, axis=1)
            logits = transformer.logits_fn(params, last, cfg_)
            return logits[:, 0], new_cache["segments"]

        self._prefill_jit = jax.jit(_prefill, static_argnames=())

        def _decode(params, cache, last_tokens, positions):
            """All slots, one token each, per-slot positions (vmap)."""
            def one(seg, tok, pos):
                # vmap strips the slot axis (axis 1 of [n_rep, B, ...]);
                # re-insert a B=1 batch dim for the model, squeeze it after.
                seg = jax.tree.map(lambda x: jnp.expand_dims(x, 1), seg)
                c = {"index": pos, "segments": seg}
                h, nc, _ = transformer.forward(
                    params, tok[None, None], cfg_, cache=c)
                logits = transformer.logits_fn(params, h, cfg_)[0, 0]
                return logits, jax.tree.map(lambda x: jnp.squeeze(x, 1),
                                            nc["segments"])

            seg_axes = jax.tree.map(lambda _: 1, cache["segments"])
            logits, new_segs = jax.vmap(
                one, in_axes=(seg_axes, 0, 0), out_axes=(0, seg_axes))(
                    cache["segments"], last_tokens, positions)
            return logits, {"index": cache["index"], "segments": new_segs}

        self._decode_jit = jax.jit(_decode)

    # ------------------------------------------------------------ plumbing
    def submit(self, req: Request):
        if len(req.prompt) > self.ecfg.max_prompt:
            raise ValueError("prompt longer than engine max_prompt")
        if len(req.prompt) < 1:
            raise ValueError("empty prompt")
        self.queue.append(req)

    def _free_slots(self):
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self):
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            T = len(req.prompt)
            Tpad = min(_next_pow2(T), self.ecfg.max_prompt) \
                if self._pad_prefill else T
            toks = np.zeros((1, Tpad), np.int32)
            toks[0, :T] = req.prompt
            # fresh zero sub-cache for the slot (kills stale recurrent state)
            seg_slot = jax.tree.map(
                lambda c: jnp.zeros((c.shape[0], 1) + c.shape[2:], c.dtype),
                self.cache["segments"])
            logits, new_seg = self._prefill_jit(
                self.params, seg_slot, jnp.asarray(toks),
                jnp.asarray(T, jnp.int32))
            # scatter the slot back into the batched cache
            self.cache["segments"] = jax.tree.map(
                lambda full, new: jax.lax.dynamic_update_slice_in_dim(
                    full, new.astype(full.dtype), slot, axis=1),
                self.cache["segments"], new_seg)
            tok = self._sample(logits[0], req)
            self.slot_req[slot] = req
            req.output.append(int(tok))
            self.slot_pos[slot] = T
            self.slot_last[slot] = int(tok)
            self.stats["prefill_calls"] += 1
            self.stats["tokens_out"] += 1

    def _sample(self, logits, req: Request):
        if req.temperature <= 0:
            return int(jnp.argmax(logits))
        key = jax.random.PRNGKey(req.seed + len(req.output))
        return int(jax.random.categorical(key, logits / req.temperature))

    def _retire(self):
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            hit_eos = (self.ecfg.eos_id >= 0 and req.output
                       and req.output[-1] == self.ecfg.eos_id)
            full = self.slot_pos[i] + 1 >= self.ecfg.max_len
            if len(req.output) >= req.max_new_tokens or hit_eos or full:
                req.done = True
                self.finished.append(req)
                self.slot_req[i] = None

    # ------------------------------------------------------------ main loop
    def step(self) -> bool:
        """One engine iteration: retire → admit → batched decode."""
        self._retire()
        self._admit()
        if not any(r is not None for r in self.slot_req):
            return False
        logits, self.cache = self._decode_jit(
            self.params, self.cache, jnp.asarray(self.slot_last),
            jnp.asarray(self.slot_pos))
        self.stats["decode_steps"] += 1
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            tok = self._sample(logits[i], req)
            req.output.append(tok)
            self.slot_pos[i] += 1
            self.slot_last[i] = tok
            self.stats["tokens_out"] += 1
        return True

    def run(self, max_iters: int = 100_000):
        it = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and it < max_iters:
            self.step()
            it += 1
        self._retire()
        done, self.finished = self.finished, []
        return done
