"""Batched serving engine: continuous-batching prefill + decode over the
model zoo's unified cache pytree (KV caches for attention layers, recurrent
state for RWKV/RG-LRU layers — `transformer.init_cache` covers all three).

Design (vLLM-style, adapted to JAX static shapes):
  · fixed engine batch of `max_batch` slots, each slot = one sequence;
  · **prefill** runs one slot at a time at its own prompt length.  For
    attention-only archs prompts are right-padded to a power-of-two bucket
    (pad keys land at positions > index and are causally masked, then
    progressively overwritten during decode, so they are never visible);
    archs with recurrent layers (rwkv/rec) use exact lengths — any padding
    would pollute the recurrent state;
  · **decode** is one jitted program for all slots, vmapped over the slot
    axis so every slot carries its own absolute position (ragged batching
    without recompiles);
  · finished slots are refilled from the queue between decode steps
    (continuous batching) — shapes never change;
  · log-quantized weights (cfg.quant == "logq6") cut weight HBM traffic
    2.67× — the dominant roofline term of decode (§Roofline).
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models import transformer
from ..obs import kernel_profile as obs_kprof
from ..obs import metrics as obs_metrics
from ..obs import trace as obs_trace


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # [T] int32
    max_new_tokens: int = 16
    temperature: float = 0.0           # 0 → greedy
    seed: int = 0
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    # host-clock lifecycle marks (perf_counter seconds), filled when
    # telemetry is on: enqueue → prefill_start → first_token → retire
    timeline: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 8
    max_prompt: int = 128
    max_len: int = 256                 # cache capacity (prompt + generation)
    eos_id: int = -1                   # -1: never stop on a token
    cache_dtype: Any = jnp.float32
    # override the model's attention dispatch for serving (None: keep the
    # model config's attn_impl).  Decode positions are traced scalars —
    # the Pallas kernel takes them as scalar-prefetch operands, so
    # "pallas" is a valid serving impl, not just "blockwise"/"ref".
    attn_impl: str | None = None
    # "auto": timeline/histogram/span work follows the obs gates
    # (REPRO_TRACE / REPRO_KERNEL_PROFILE); "on"/"off" force it.  The
    # `stats` counters are always maintained (backwards-compat view).
    telemetry: str = "auto"


_NULL_CTX = contextlib.nullcontext()


def _has_recurrence(cfg) -> bool:
    return any(k in ("rwkv", "rec") for k in cfg.layer_pattern)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class ServeEngine:
    def __init__(self, cfg, params, ecfg: EngineConfig = EngineConfig()):
        if not cfg.embed_inputs:
            raise ValueError("engine serves token archs; frontend-stub archs "
                             "(musicgen) are driven via launch/serve.py "
                             "embeddings path")
        if ecfg.attn_impl is not None:
            cfg = dataclasses.replace(cfg, attn_impl=ecfg.attn_impl)
        if ecfg.telemetry not in ("auto", "on", "off"):
            raise ValueError(f"telemetry must be auto|on|off, got "
                             f"{ecfg.telemetry!r}")
        self.cfg, self.params, self.ecfg = cfg, params, ecfg
        B, L = ecfg.max_batch, ecfg.max_len
        self.cache = transformer.init_cache(cfg, B, L, ecfg.cache_dtype)
        self._pad_prefill = not _has_recurrence(cfg)
        # per-slot host state
        self.slot_req: list[Request | None] = [None] * B
        self.slot_pos = np.zeros(B, np.int32)      # next write position
        self.slot_last = np.zeros(B, np.int32)     # last emitted token
        self.queue: deque[Request] = deque()       # O(1) FIFO admission
        self.finished: list[Request] = []
        # per-engine registry so concurrent engines (and tests) stay
        # isolated; `stats` below is a compat view over these counters.
        self.metrics = obs_metrics.MetricsRegistry()
        self._c_prefill = self.metrics.counter("serve_prefill_calls")
        self._c_decode = self.metrics.counter("serve_decode_steps")
        self._c_tokens = self.metrics.counter("serve_tokens_out")
        self._c_retired = self.metrics.counter("serve_requests_retired")
        self._g_queue = self.metrics.gauge("serve_queue_depth")
        self._g_slots = self.metrics.gauge("serve_slots_busy")
        self._h_ttft = self.metrics.histogram("serve_ttft_s")
        self._h_step = self.metrics.histogram("serve_decode_step_s")
        self._h_prefill = self.metrics.histogram("serve_prefill_s")
        self._h_tps = self.metrics.histogram(
            "serve_tokens_per_s", bounds=obs_metrics.RATE_BUCKETS)

        cfg_ = cfg

        def _prefill(params, seg_slot, tokens, length):
            """One slot.  seg_slot: cache segments sliced to B=1 and zeroed.
            tokens: [1, Tpad]; length: real length (static via bucket)."""
            cache = {"index": jnp.zeros((), jnp.int32), "segments": seg_slot}
            h, new_cache, _ = transformer.forward(
                params, tokens, cfg_, cache=cache)
            last = jax.lax.dynamic_slice_in_dim(h, length - 1, 1, axis=1)
            logits = transformer.logits_fn(params, last, cfg_)
            return logits[:, 0], new_cache["segments"]

        self._prefill_jit = jax.jit(_prefill, static_argnames=())

        def _decode(params, cache, last_tokens, positions):
            """All slots, one token each, per-slot positions (vmap)."""
            def one(seg, tok, pos):
                # vmap strips the slot axis (axis 1 of [n_rep, B, ...]);
                # re-insert a B=1 batch dim for the model, squeeze it after.
                seg = jax.tree.map(lambda x: jnp.expand_dims(x, 1), seg)
                c = {"index": pos, "segments": seg}
                h, nc, _ = transformer.forward(
                    params, tok[None, None], cfg_, cache=c)
                logits = transformer.logits_fn(params, h, cfg_)[0, 0]
                return logits, jax.tree.map(lambda x: jnp.squeeze(x, 1),
                                            nc["segments"])

            seg_axes = jax.tree.map(lambda _: 1, cache["segments"])
            logits, new_segs = jax.vmap(
                one, in_axes=(seg_axes, 0, 0), out_axes=(0, seg_axes))(
                    cache["segments"], last_tokens, positions)
            return logits, {"index": cache["index"], "segments": new_segs}

        self._decode_jit = jax.jit(_decode)

    # ----------------------------------------------------------- telemetry
    def _telemetry_on(self) -> bool:
        mode = self.ecfg.telemetry
        if mode == "off":
            return False
        if mode == "on":
            return True
        return obs_trace.TRACER.enabled() or obs_kprof.PROFILER.enabled()

    @property
    def stats(self) -> dict:
        """Backwards-compatible counter view (always maintained)."""
        return {"prefill_calls": int(self._c_prefill.value),
                "decode_steps": int(self._c_decode.value),
                "tokens_out": int(self._c_tokens.value)}

    def metrics_snapshot(self) -> dict:
        """One JSON-able dict with everything measured so far: the
        engine's own registry (TTFT/tokens-per-s histograms, gauges,
        counters), the process-wide kernel-dispatch records (per-op impl,
        bytes moved, compile/steady µs), and the default registry
        (autotune hit/miss, kernel-dispatch histograms)."""
        return {"engine": self.metrics.snapshot(),
                "stats": self.stats,
                "kernels": obs_kprof.PROFILER.snapshot(),
                "global": obs_metrics.REGISTRY.snapshot()}

    # ------------------------------------------------------------ plumbing
    def submit(self, req: Request):
        if len(req.prompt) > self.ecfg.max_prompt:
            raise ValueError("prompt longer than engine max_prompt")
        if len(req.prompt) < 1:
            raise ValueError("empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got "
                             f"{req.max_new_tokens} (request {req.uid})")
        self.queue.append(req)
        if self._telemetry_on():
            req.timeline["enqueue"] = time.perf_counter()
            self._g_queue.set(len(self.queue))
            obs_trace.instant("enqueue", uid=req.uid,
                              prompt_len=len(req.prompt))

    def _free_slots(self):
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self):
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.popleft()
            tele = self._telemetry_on()
            t0 = time.perf_counter() if tele else 0.0
            T = len(req.prompt)
            with obs_trace.span("prefill", uid=req.uid, slot=slot,
                                tokens=T) if tele else _NULL_CTX:
                if tele:
                    req.timeline["prefill_start"] = t0
                Tpad = min(_next_pow2(T), self.ecfg.max_prompt) \
                    if self._pad_prefill else T
                toks = np.zeros((1, Tpad), np.int32)
                toks[0, :T] = req.prompt
                # fresh zero sub-cache for the slot (kills stale recurrent
                # state)
                seg_slot = jax.tree.map(
                    lambda c: jnp.zeros((c.shape[0], 1) + c.shape[2:],
                                        c.dtype),
                    self.cache["segments"])
                run_prefill = lambda: self._prefill_jit(
                    self.params, seg_slot, jnp.asarray(toks),
                    jnp.asarray(T, jnp.int32))
                logits, new_seg = (
                    obs_kprof.PROFILER.time_program("prefill", run_prefill)
                    if tele else run_prefill())
                # scatter the slot back into the batched cache
                self.cache["segments"] = jax.tree.map(
                    lambda full, new: jax.lax.dynamic_update_slice_in_dim(
                        full, new.astype(full.dtype), slot, axis=1),
                    self.cache["segments"], new_seg)
                tok = self._sample(logits[0], req)
                self.slot_req[slot] = req
                req.output.append(int(tok))
                self.slot_pos[slot] = T
                self.slot_last[slot] = int(tok)
            self._c_prefill.inc()
            self._c_tokens.inc()
            if tele:
                now = time.perf_counter()
                req.timeline["first_token"] = now
                self._h_prefill.record(now - t0)
                self._h_ttft.record(now - req.timeline.get("enqueue", t0))
                self._g_queue.set(len(self.queue))

    def _sample(self, logits, req: Request):
        if req.temperature <= 0:
            return int(jnp.argmax(logits))
        key = jax.random.PRNGKey(req.seed + len(req.output))
        return int(jax.random.categorical(key, logits / req.temperature))

    def _retire(self):
        tele = self._telemetry_on()
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            hit_eos = (self.ecfg.eos_id >= 0 and req.output
                       and req.output[-1] == self.ecfg.eos_id)
            full = self.slot_pos[i] + 1 >= self.ecfg.max_len
            if len(req.output) >= req.max_new_tokens or hit_eos or full:
                req.done = True
                self.finished.append(req)
                self.slot_req[i] = None
                self._c_retired.inc()
                if tele:
                    now = time.perf_counter()
                    req.timeline["retire"] = now
                    dur = now - req.timeline.get("prefill_start", now)
                    if dur > 0 and req.output:
                        self._h_tps.record(len(req.output) / dur)
                    obs_trace.instant("retire", uid=req.uid,
                                      tokens=len(req.output))

    # ------------------------------------------------------------ main loop
    def step(self) -> bool:
        """One engine iteration: retire → admit → batched decode."""
        self._retire()
        self._admit()
        busy = sum(r is not None for r in self.slot_req)
        if not busy:
            return False
        tele = self._telemetry_on()
        if tele:
            self._g_slots.set(busy)
            self._g_queue.set(len(self.queue))
            t0 = time.perf_counter()
        run_decode = lambda: self._decode_jit(
            self.params, self.cache, jnp.asarray(self.slot_last),
            jnp.asarray(self.slot_pos))
        logits, self.cache = (
            obs_kprof.PROFILER.time_program("decode", run_decode)
            if tele else run_decode())
        self._c_decode.inc()
        for i, req in enumerate(self.slot_req):
            if req is None:
                continue
            tok = self._sample(logits[i], req)
            req.output.append(tok)
            self.slot_pos[i] += 1
            self.slot_last[i] = tok
            self._c_tokens.inc()
        if tele:
            self._h_step.record(time.perf_counter() - t0)
        return True

    def run(self, max_iters: int = 100_000):
        it = 0
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and it < max_iters:
            self.step()
            it += 1
        self._retire()
        done, self.finished = self.finished, []
        return done
