"""Serving-time weight quantization: replace matmul kernels with packed
6-bit(+sign) base-√2 QuantizedTensors (the paper's storage format).

On TPU the packed codes are decoded in VMEM by the log_matmul Pallas kernel
right next to the MXU — weight HBM traffic drops 4× vs f32 / 2.67× vs bf16,
which is the dominant term of weight-bound decode.  The CPU/XLA fallback
decodes via jnp (fused where XLA can); tests assert numerical equivalence
to dequantize-then-matmul.
"""

from __future__ import annotations

import jax

from ..core.logquant import LogQuantConfig, QuantizedTensor, quantize_tensor

# matmul kernels eligible for packed serving weights (2D [in, out] layout;
# embeddings stay fp — gathers don't go through log_matmul)
QUANT_LEAVES = frozenset(
    {"wq", "wk", "wv", "wo", "w1", "w2", "w3",
     "ck", "cv", "cr", "wg", "wr"})


def _leaf_name(path) -> str | None:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return entry.key
    return None


def quantize_params(params, qcfg: LogQuantConfig = LogQuantConfig()):
    """Pack every eligible kernel; leaves stacked scan dims intact (the
    layer scan slices the QuantizedTensor's children per iteration)."""
    import jax.numpy as jnp

    def leaf(path, x):
        name = _leaf_name(path)
        if name in QUANT_LEAVES and x.ndim >= 2:
            qt = quantize_tensor(x, qcfg)
            if x.ndim >= 3:
                # stacked scan leaf [n_rep, K, N]: the layer scan slices
                # every child along axis 0, so the scale must carry the
                # n_rep dim too.
                scale = jnp.broadcast_to(
                    qt.scale, (x.shape[0],) + qt.scale.shape[1:])
                qt = QuantizedTensor(qt.packed, scale, qt.cfg)
            return qt
        return x

    return jax.tree_util.tree_map_with_path(leaf, params)


def abstract_quantized_params(params_abs, qcfg: LogQuantConfig =
                              LogQuantConfig()):
    """ShapeDtypeStruct version (dry-run path, no allocation)."""
    return jax.eval_shape(lambda p: quantize_params(p, qcfg), params_abs)


def quantized_fraction(params) -> float:
    """Fraction of parameter bytes now stored as 1-byte codes."""
    import jax.numpy as jnp
    total = packed = 0
    for x in jax.tree_util.tree_leaves(params):
        n = x.size * getattr(x.dtype, "itemsize", 4)
        total += n
        if x.dtype == jnp.int8:
            packed += n
    return packed / max(total, 1)
