"""Serving-time weight quantization: replace matmul/conv kernels with packed
6-bit(+sign) base-√2 QuantizedTensors (the paper's storage format).

On TPU the packed codes are decoded in VMEM by the log_matmul / log_conv2d
Pallas kernels right next to the MXU — weight HBM traffic drops 4× vs f32 /
2.67× vs bf16, which is the dominant term of weight-bound decode.  The
CPU/XLA fallback decodes via jnp (fused where XLA can); tests assert
numerical equivalence to dequantize-then-matmul.

`quantize_params` packs transformer/LM matmul kernels;
`quantize_cnn_params` packs a CNN's 4-D conv kernels once at load, so the
model's convs dispatch straight onto the log-conv stack
(`kernels/ops.conv2d`) with no per-step packing.
"""

from __future__ import annotations

import jax

from ..core.logquant import (LogQuantConfig, QuantizedTensor, _scale_for,
                             log_quantize, quantize_tensor)

# matmul kernels eligible for packed serving weights (2D [in, out] layout;
# embeddings stay fp — gathers don't go through log_matmul)
QUANT_LEAVES = frozenset(
    {"wq", "wk", "wv", "wo", "w1", "w2", "w3",
     "ck", "cv", "cr", "wg", "wr"})


def _leaf_name(path) -> str | None:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return entry.key
    return None


def quantize_params(params, qcfg: LogQuantConfig = LogQuantConfig()):
    """Pack every eligible kernel; leaves stacked scan dims intact (the
    layer scan slices the QuantizedTensor's children per iteration)."""
    import jax.numpy as jnp

    def leaf(path, x):
        name = _leaf_name(path)
        if name in QUANT_LEAVES and x.ndim >= 2:
            if x.ndim >= 3:
                # stacked scan leaf [n_rep, K, N]: the layer scan slices
                # every child along axis 0, so scale per (rep, channel) —
                # the same grid fake-quant sees on each sliced [K, N]
                # (a rep-collapsed max would quantize on a coarser grid).
                axis = tuple(range(1, x.ndim - 1)) if qcfg.per_channel \
                    else tuple(range(1, x.ndim))
                packed, scale = log_quantize(x, qcfg,
                                             scale=_scale_for(x, qcfg, axis))
                return QuantizedTensor(packed, scale, qcfg, x.shape)
            return quantize_tensor(x, qcfg)
        return x

    return jax.tree_util.tree_map_with_path(leaf, params)


def quantize_cnn_params(params, qcfg: LogQuantConfig = LogQuantConfig(),
                        conv_layout: str | None = None):
    """Pack every conv kernel (4-D ``w`` leaf: [K, K, Cin_g, Cout]) of a
    `models/cnn.py` parameter tree into a `QuantizedTensor` — one packing
    at load time, per-output-channel scales.  Biases and the 2-D dense head
    stay fp (gathers/heads don't go through the log kernels).

    ``conv_layout="conv_taps"`` additionally pre-reshapes each packed code
    array to the tap-major ``[K*K, Cin_g, Cout]`` layout the fused Pallas
    conv kernel streams from HBM, recorded as a layout hint on the
    `QuantizedTensor` so `ops.conv2d` skips the per-call reshape.

    ``conv_layout="lane_packed"`` goes one further for depthwise kernels:
    a ``[K, K, 1, Cout]`` leaf must be a ``groups=Cout`` depthwise conv
    (param trees don't store ``groups`` — any other group count is
    ambiguous at load time), so its codes are pre-arranged into the
    128-lane superblock layout ``[n_sb, K*K, g_b*cin_lane, 1]`` the
    lane-packed kernel streams directly (``layout_meta=(g_b, cin_lane,
    groups)``).  Non-depthwise leaves fall back to ``conv_taps``; if the
    call-site ``groups`` disagrees with the baked map, `ops.conv2d`
    unpacks gracefully."""
    assert conv_layout in (None, "conv_taps", "lane_packed"), conv_layout
    from ..kernels.log_conv2d import lane_pack_codes, lane_pack_geometry

    def leaf(path, x):
        if _leaf_name(path) == "w" and getattr(x, "ndim", 0) == 4:
            qt = quantize_tensor(x, qcfg)
            K1, K2, cin_g, cout = x.shape
            if conv_layout == "lane_packed" and cin_g == 1:
                lp = lane_pack_geometry(cout, cin_g)
                if lp["g_b"] > 1:
                    codes = lane_pack_codes(qt.packed, cout, lp["g_b"],
                                            lp["cin_lane"])
                    return QuantizedTensor(
                        codes, jax.numpy.reshape(qt.scale, (-1,)),
                        qcfg, x.shape, layout="lane_packed",
                        layout_meta=(lp["g_b"], lp["cin_lane"], cout))
            if conv_layout in ("conv_taps", "lane_packed"):
                return QuantizedTensor(
                    qt.packed.reshape(K1 * K2, cin_g, cout),
                    jax.numpy.reshape(qt.scale, (1, 1, -1)),
                    qcfg, x.shape, layout="conv_taps")
            return qt
        return x

    return jax.tree_util.tree_map_with_path(leaf, params)


def abstract_quantized_params(params_abs, qcfg: LogQuantConfig =
                              LogQuantConfig()):
    """ShapeDtypeStruct version (dry-run path, no allocation)."""
    return jax.eval_shape(lambda p: quantize_params(p, qcfg), params_abs)


def abstract_quantized_cnn_params(params_abs, qcfg: LogQuantConfig =
                                  LogQuantConfig(),
                                  conv_layout: str | None = None):
    """ShapeDtypeStruct version of `quantize_cnn_params` — what the packed
    tree will look like, without materialising weights.  The cold-start
    benchmark and the autotune warm-start tooling trace quantized CNN
    dispatch through this path: layouts (``conv_taps``/``lane_packed``)
    resolve from shapes alone, and `ops.conv2d`'s autotune keys only
    depend on shapes + `qcfg`, so abstract packing exercises the exact
    table lookups real serving performs."""
    return jax.eval_shape(
        lambda p: quantize_cnn_params(p, qcfg, conv_layout=conv_layout),
        params_abs)


def quantized_fraction(params) -> float:
    """Fraction of parameter bytes now stored as 1-byte codes."""
    import jax.numpy as jnp
    total = packed = 0
    for x in jax.tree_util.tree_leaves(params):
        if not hasattr(x, "dtype"):  # e.g. python-int strides in CNN trees
            continue
        n = x.size * getattr(x.dtype, "itemsize", 4)
        total += n
        if x.dtype == jnp.int8:
            packed += n
    return packed / max(total, 1)
