from .optimizer import (OptimizerConfig, adamw_init, adamw_update,  # noqa
                        make_optimizer, sgd_init, sgd_update)
from .grad_compress import (CompressorState, compress_decompress,   # noqa
                            log_compress_gradients, make_compressor)
from .train_loop import TrainConfig, TrainState, make_train_step, train  # noqa
