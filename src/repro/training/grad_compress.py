"""Log-quantized gradient compression with error feedback — the paper's
6-bit base-√2 codes applied beyond the paper, to the data-parallel
all-reduce.

Mechanism (EF-SGD style):
    acc   = grad + error                       # fold in residual
    q     = log_dequantize(log_quantize(acc))  # 7-bit wire format (6+sign)
    error = acc - q                            # kept locally, fp32
    return q                                   # what crosses the network

The all-reduce then moves 7-bit codes (+ one fp32 scale per tensor) instead
of 32/16-bit floats — a 4.6×/2.3× cut of the collective roofline term on
slow cross-pod links.  On real hardware the psum happens over *decoded*
values (log codes are not additive); GSPMD sees the decoded tensor, so this
transform is sharding-transparent: we model the wire win in
analysis/roofline.py via `wire_bytes_fraction`.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core.logquant import DEFAULT, LogQuantConfig, log_dequantize, \
    log_quantize


@dataclasses.dataclass(frozen=True)
class CompressorConfig:
    enabled: bool = True
    qcfg: LogQuantConfig = DEFAULT
    min_size: int = 1024     # tiny tensors (norm scales) go uncompressed


CompressorState = dict  # {"error": pytree of fp32 residuals}


def _compressible(leaf, cfg: CompressorConfig) -> bool:
    return leaf.size >= cfg.min_size


def compressor_init(params, cfg: CompressorConfig = CompressorConfig()) \
        -> CompressorState:
    err = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32)
        if _compressible(p, cfg) else jnp.zeros((), jnp.float32), params)
    return {"error": err}


def compress_decompress(g, cfg: LogQuantConfig = DEFAULT):
    """Round-trip one tensor through the wire format (fp32 in/out)."""
    packed, scale = log_quantize(g.astype(jnp.float32), cfg)
    return log_dequantize(packed, scale, cfg, dtype=jnp.float32)


def log_compress_gradients(grads, state: CompressorState,
                           cfg: CompressorConfig = CompressorConfig()):
    """Apply EF log-compression leaf-wise.  Returns (grads', state')."""
    if not cfg.enabled:
        return grads, state

    def leaf(g, e):
        if not _compressible(g, cfg):
            return g.astype(jnp.float32), e
        acc = g.astype(jnp.float32) + e
        q = compress_decompress(acc, cfg.qcfg)
        return q, acc - q

    flat = jax.tree.map(leaf, grads, state["error"])
    new_g = jax.tree.map(lambda pair: pair[0], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda pair: pair[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_g, {"error": new_e}


def make_compressor(params, enabled: bool = True,
                    qcfg: LogQuantConfig = DEFAULT, min_size: int = 1024):
    cfg = CompressorConfig(enabled=enabled, qcfg=qcfg, min_size=min_size)
    return compressor_init(params, cfg), \
        lambda g, s: log_compress_gradients(g, s, cfg)


def wire_bytes_fraction(qcfg: LogQuantConfig = DEFAULT,
                        ref_bits: int = 32) -> float:
    """Fraction of all-reduce bytes left on the wire after compression."""
    return (qcfg.storage_bits) / ref_bits
