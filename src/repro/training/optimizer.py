"""Optimizers in pure JAX: AdamW, SGD(+momentum), LR schedules, clipping.

Everything is a (init, update) pair over pytrees so it composes with pjit —
optimizer state inherits each parameter's sharding via GSPMD.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"               # adamw | sgd
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"          # cosine | linear | constant
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    momentum: float = 0.9
    grad_clip: float = 1.0


# -------------------------------------------------------------- schedules
def lr_at(cfg: OptimizerConfig, step) -> jnp.ndarray:
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) \
            * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_ratio) * frac
    else:
        decay = 1.0
    return cfg.lr * warm * decay


# -------------------------------------------------------------- clipping
def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gn


# -------------------------------------------------------------- AdamW
def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: OptimizerConfig, grads, state, params):
    count = state["count"] + 1
    lr = lr_at(cfg, count - 1)
    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state["mu"], grads)
    nu = jax.tree.map(
        lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state["nu"], grads)
    c1 = 1 - b1 ** count.astype(jnp.float32)
    c2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(p, m, v):
        step = (m / c1) / (jnp.sqrt(v / c2) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, {"mu": mu, "nu": nu, "count": count}


# -------------------------------------------------------------- SGD
def sgd_init(params):
    return {"mom": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params),
            "count": jnp.zeros((), jnp.int32)}


def sgd_update(cfg: OptimizerConfig, grads, state, params):
    count = state["count"] + 1
    lr = lr_at(cfg, count - 1)
    mom = jax.tree.map(
        lambda m, g: cfg.momentum * m + g.astype(jnp.float32),
        state["mom"], grads)
    new_params = jax.tree.map(
        lambda p, m: (p.astype(jnp.float32)
                      - lr * (m + cfg.weight_decay * p.astype(jnp.float32))
                      ).astype(p.dtype),
        params, mom)
    return new_params, {"mom": mom, "count": count}


def make_optimizer(cfg: OptimizerConfig):
    if cfg.name == "adamw":
        return adamw_init, lambda g, s, p: adamw_update(cfg, g, s, p)
    if cfg.name == "sgd":
        return sgd_init, lambda g, s, p: sgd_update(cfg, g, s, p)
    raise ValueError(cfg.name)
