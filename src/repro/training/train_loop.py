"""Training loop: grad-accumulation microbatching, metrics, hooks,
checkpoint integration.  Model-agnostic — works for every assigned arch and
the CNN substrate via a `loss_fn(params, batch) -> (loss, metrics)`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from ..obs import trace as obs_trace
from .grad_compress import CompressorConfig, compressor_init, \
    log_compress_gradients
from .optimizer import OptimizerConfig, clip_by_global_norm, make_optimizer


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptimizerConfig = OptimizerConfig()
    microbatches: int = 1            # grad accumulation factor
    grad_compress: bool = False      # log-quant EF compression
    log_every: int = 10
    ckpt_every: int = 0              # 0 = never
    xent_chunk: int = 512


TrainState = dict  # {"params", "opt", "compress", "step"}


def init_train_state(params, cfg: TrainConfig) -> TrainState:
    opt_init, _ = make_optimizer(cfg.opt)
    state = {"params": params, "opt": opt_init(params),
             "step": jnp.zeros((), jnp.int32)}
    if cfg.grad_compress:
        state["compress"] = compressor_init(params)
    return state


def make_train_step(loss_fn: Callable, cfg: TrainConfig):
    """loss_fn(params, microbatch) -> (loss, metrics dict of scalars).

    The returned step takes (state, batch) where batch leaves have leading
    dim = microbatches × per-micro batch; accumulation runs as a scan so
    peak activation memory is one microbatch.
    """
    _, opt_update = make_optimizer(cfg.opt)
    ccfg = CompressorConfig(enabled=cfg.grad_compress)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return loss, metrics, grads

    def step(state: TrainState, batch):
        params = state["params"]
        if cfg.microbatches > 1:
            def split(x):
                mb = cfg.microbatches
                return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def body(carry, mb):
                acc, loss_sum = carry
                loss, _, g = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_sum + loss), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, loss_sum), _ = jax.lax.scan(
                body, (zero, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / cfg.microbatches, gsum)
            loss = loss_sum / cfg.microbatches
            metrics = {}
        else:
            loss, metrics, grads = grads_of(params, batch)

        if cfg.grad_compress:
            grads, new_comp = log_compress_gradients(
                grads, state["compress"], ccfg)

        grads, gnorm = clip_by_global_norm(grads, cfg.opt.grad_clip)
        new_params, new_opt = opt_update(grads, state["opt"], params)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        if cfg.grad_compress:
            new_state["compress"] = new_comp
        out_metrics = {"loss": loss, "grad_norm": gnorm, **metrics}
        return new_state, out_metrics

    return step


def train(loss_fn, params, loader, cfg: TrainConfig, *, num_steps: int,
          start_step: int = 0, state: TrainState | None = None,
          hooks: list[Callable] | None = None, jit: bool = True,
          donate: bool = True, metrics: Any = None, monitor: Any = None,
          host: str = "host0"):
    """Run `num_steps` steps.  Returns (state, history).

    hooks: callables (step:int, state, metrics:dict) -> None, run on host
    every cfg.log_every steps (checkpointing, straggler heartbeats, …).

    Telemetry: `metrics` (an `obs.metrics.MetricsRegistry`) gets a
    ``train_step_s`` histogram, and `monitor` (a
    `runtime.monitor.HeartbeatMonitor`) gets a ``record(host, step, dt)``
    heartbeat — both fed from the **same per-step wall-time event**, so
    fleet-health straggler detection and the step-time percentiles can
    never disagree about what was measured.  Measuring a truthful per-step
    time requires a `block_until_ready` sync per step, so it only happens
    when a consumer (metrics/monitor/active tracer) is attached.
    """
    state = state if state is not None else init_train_state(params, cfg)
    step_fn = make_train_step(loss_fn, cfg)
    if jit:
        step_fn = jax.jit(step_fn, donate_argnums=(0,) if donate else ())
    step_hist = (metrics.histogram("train_step_s")
                 if metrics is not None else None)

    def emit_step(step, dt_s, t0_ns, dur_ns):
        # the single step-event source feeding every telemetry consumer
        if step_hist is not None:
            step_hist.record(dt_s)
        if monitor is not None:
            monitor.record(host, step, dt_s)
        obs_trace.add_complete("train_step", t0_ns, dur_ns, step=step)

    history = []
    t0 = time.perf_counter()
    for step in range(start_step, start_step + num_steps):
        batch = loader.batch(step) if hasattr(loader, "batch") \
            else next(loader)
        timed = (step_hist is not None or monitor is not None
                 or obs_trace.enabled())
        if timed:
            ts0 = time.perf_counter_ns()
            state, step_metrics = step_fn(state, batch)
            jax.block_until_ready(step_metrics)
            dur = time.perf_counter_ns() - ts0
            emit_step(step, dur / 1e9, ts0, dur)
        else:
            state, step_metrics = step_fn(state, batch)
        if cfg.log_every and (step % cfg.log_every == 0
                              or step == start_step + num_steps - 1):
            step_metrics = {k: float(v) for k, v in step_metrics.items()}
            step_metrics["step"] = step
            step_metrics["wall_s"] = time.perf_counter() - t0
            history.append(step_metrics)
            for h in (hooks or []):
                h(step, state, step_metrics)
    return state, history
