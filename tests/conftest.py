"""Suite-wide config: a minimal `hypothesis` fallback.

`hypothesis` is an optional `[test]` extra (see pyproject.toml).  When it is
absent the property tests would crash the whole collection with
ModuleNotFoundError; instead we install a tiny stand-in that runs each
property against deterministic pseudo-random examples.  It covers exactly the
API surface this suite uses (`given`, `settings`, and the `integers`,
`floats`, `lists`, `sampled_from`, `booleans` strategies) — no shrinking, no
database, just honest example generation so the properties still execute.
"""

from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib


def _install_hypothesis_fallback() -> None:
    class Strategy:
        def __init__(self, sample):
            self._sample = sample

        def example(self, rng):
            return self._sample(rng)

    def integers(min_value=-(2 ** 31), max_value=2 ** 31 - 1):
        return Strategy(lambda rng: rng.randint(min_value, max_value))

    def floats(min_value=-1e9, max_value=1e9, allow_nan=False,
               allow_infinity=False, width=64):
        return Strategy(lambda rng: rng.uniform(min_value, max_value))

    def booleans():
        return Strategy(lambda rng: rng.random() < 0.5)

    def sampled_from(elements):
        elements = list(elements)
        return Strategy(lambda rng: rng.choice(elements))

    def lists(elements, min_size=0, max_size=10):
        def sample(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example(rng) for _ in range(n)]
        return Strategy(sample)

    def given(*strategies, **kw_strategies):
        def decorate(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples", 25)
                # deterministic per-test seed so failures reproduce
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    vals = [s.example(rng) for s in strategies]
                    kws = {k: s.example(rng)
                           for k, s in kw_strategies.items()}
                    fn(*args, *vals, **kwargs, **kws)
            # pytest must not mistake the property's arguments for fixtures
            wrapper.__signature__ = inspect.Signature()
            del wrapper.__wrapped__
            return wrapper
        return decorate

    def settings(max_examples=100, deadline=None, **_ignored):
        def decorate(fn):
            fn._max_examples = max_examples
            return fn
        return decorate

    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.booleans = booleans
    st.sampled_from = sampled_from
    st.lists = lists

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    hyp.__is_fallback__ = True

    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


try:  # pragma: no cover - prefer the real thing when installed
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_fallback()
