"""The op-keyed block-size autotuner: layered table resolution (user
tier over the packaged warm-start tier), persistence (including the
concurrent-writer merge), keying (conv2d and attention namespaces),
invalidation, candidate filtering, and numerics of tuned configs."""

import json
import os
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops
from repro.core.logquant import LogQuantConfig, quantize_tensor
from repro.obs import metrics as obs_metrics

SHAPE = dict(B=1, H=8, W=8, C=5, K=3, Cout=7)
ARGS = (1, 8, 8, 5, 3, 7)

REAL_PACKAGED_DIR = autotune.PACKAGED_DIR  # before the fixture repoints it


@pytest.fixture(autouse=True)
def _isolated_table(tmp_path, monkeypatch):
    """Every test gets its own on-disk user table AND an empty packaged
    tier; caches are reset so nothing leaks between tests (or into the
    user's real cache dir / the checked-in warm-start tables)."""
    monkeypatch.setenv("REPRO_AUTOTUNE_PATH", str(tmp_path / "table.json"))
    monkeypatch.setattr(autotune, "PACKAGED_DIR",
                        str(tmp_path / "packaged"))
    autotune.reset_cache()
    yield
    autotune.reset_cache()


def _write_packaged(backend: str, entries: dict) -> str:
    os.makedirs(autotune.PACKAGED_DIR, exist_ok=True)
    path = autotune.packaged_table_path(backend)
    with open(path, "w") as f:
        json.dump({"version": autotune.SCHEMA_VERSION, "entries": entries},
                  f)
    return path


def _lookup_counts(op="conv2d") -> dict:
    out = {"hit_user": 0, "hit_warm": 0, "miss": 0}
    for r in out:
        out[r] = obs_metrics.REGISTRY.counter("autotune_lookup", op=op,
                                              result=r).value
    return out


def test_key_carries_shape_stride_groups_backend():
    k1 = autotune.conv_key(*ARGS, backend="cpu")
    assert autotune.conv_key(*ARGS, backend="cpu") == k1  # deterministic
    for other in (autotune.conv_key(1, 8, 8, 5, 3, 9, backend="cpu"),
                  autotune.conv_key(*ARGS, stride=2, backend="cpu"),
                  autotune.conv_key(*ARGS, padding="VALID", backend="cpu"),
                  autotune.conv_key(*ARGS, backend="tpu"),
                  autotune.conv_key(*ARGS, cfg=LogQuantConfig(bits=4),
                                    backend="cpu")):
        assert other != k1


def test_record_lookup_roundtrip_persists():
    key = autotune.conv_key(*ARGS, backend="cpu")
    cfg = dict(block_cin=4, block_cout=8, rows_per_tile=4, batch_per_tile=1)
    autotune.record(key, cfg, 12.5)
    assert autotune.lookup(key) == cfg
    autotune.reset_cache()          # force re-read from disk
    assert autotune.lookup(key) == cfg
    table = json.load(open(autotune.table_path()))
    assert table["version"] == autotune.SCHEMA_VERSION
    assert table["entries"][key]["us"] == 12.5


def test_stale_schema_version_invalidates_table():
    key = autotune.conv_key(*ARGS, backend="cpu")
    autotune.record(key, dict(block_cin=4), 1.0)
    autotune.reset_cache()
    path = autotune.table_path()
    table = json.load(open(path))
    table["version"] = autotune.SCHEMA_VERSION - 1
    json.dump(table, open(path, "w"))
    assert autotune.lookup(key) is None  # stale entries are not served


def test_corrupt_table_is_ignored():
    with open(autotune.table_path(), "w") as f:
        f.write("{not json")
    assert autotune.lookup("anything") is None
    autotune.record("k", dict(block_cin=4), 1.0)  # and is recoverable
    autotune.reset_cache()
    assert autotune.lookup("k") == dict(block_cin=4)


# ------------------------------------------------- layered warm-start tier


def test_layered_lookup_precedence_and_counter_labels():
    """User tier (env path / user cache) shadows the packaged tier; each
    resolution increments its own `autotune_lookup` result label."""
    key = autotune.conv_key(*ARGS, backend="cpu")
    c0 = _lookup_counts()
    assert autotune.lookup(key) is None                    # nothing anywhere
    _write_packaged("cpu", {key: {"config": dict(block_cin=8), "us": 1.0}})
    autotune.reset_cache()
    assert autotune.lookup(key) == dict(block_cin=8)       # packaged tier
    autotune.record(key, dict(block_cin=4), 2.0)
    assert autotune.lookup(key) == dict(block_cin=4)       # user tier wins
    c1 = _lookup_counts()
    assert {r: c1[r] - c0[r] for r in c1} == \
        {"miss": 1, "hit_warm": 1, "hit_user": 1}


def test_packaged_tier_keyed_per_backend():
    key_cpu = autotune.conv_key(*ARGS, backend="cpu")
    key_tpu = autotune.conv_key(*ARGS, backend="tpu")
    _write_packaged("cpu", {key_cpu: {"config": dict(block_cin=8),
                                      "us": 1.0}})
    assert autotune.lookup(key_cpu) == dict(block_cin=8)
    assert autotune.lookup(key_tpu) is None  # no tpu.json → miss, no error


def test_env_path_overrides_user_cache(tmp_path, monkeypatch):
    """$REPRO_AUTOTUNE_PATH beats ~/.cache/repro/… — both are the user
    tier, the env var just repoints it."""
    key = autotune.conv_key(*ARGS, backend="cpu")
    monkeypatch.delenv("REPRO_AUTOTUNE_PATH")
    monkeypatch.setenv("HOME", str(tmp_path / "home"))
    autotune.reset_cache()
    assert autotune.table_path() == str(
        tmp_path / "home" / ".cache" / "repro" / "kernel_autotune.json")
    autotune.record(key, dict(block_cin=2), 1.0)           # lands in ~/.cache
    autotune.reset_cache()
    assert autotune.lookup(key) == dict(block_cin=2)
    monkeypatch.setenv("REPRO_AUTOTUNE_PATH",
                       str(tmp_path / "env_table.json"))
    autotune.reset_cache()
    assert autotune.lookup(key) is None                    # env tier shadows
    autotune.record(key, dict(block_cin=16), 1.0)
    autotune.reset_cache()
    assert autotune.lookup(key) == dict(block_cin=16)


def test_record_never_writes_packaged_tier():
    key = autotune.conv_key(*ARGS, backend="cpu")
    path = _write_packaged("cpu", {key: {"config": dict(block_cin=8),
                                         "us": 1.0}})
    before = open(path).read()
    autotune.record(key, dict(block_cin=4), 2.0)
    assert open(path).read() == before                 # packaged: read-only
    user = json.load(open(autotune.table_path()))
    assert user["entries"][key]["config"] == dict(block_cin=4)


def test_stale_packaged_schema_is_ignored():
    key = autotune.conv_key(*ARGS, backend="cpu")
    os.makedirs(autotune.PACKAGED_DIR, exist_ok=True)
    with open(autotune.packaged_table_path("cpu"), "w") as f:
        json.dump({"version": autotune.SCHEMA_VERSION - 1,
                   "entries": {key: {"config": dict(block_cin=8)}}}, f)
    assert autotune.lookup(key) is None


def test_checked_in_tables_cover_the_zoo(monkeypatch):
    """The real packaged tier resolves every conv dispatch of the four
    paper CNNs (the cold-start acceptance, on one network for speed)."""
    from repro.models.cnn import trace_conv_shapes
    monkeypatch.setattr(autotune, "PACKAGED_DIR", REAL_PACKAGED_DIR)
    autotune.reset_cache()
    shapes = trace_conv_shapes("mobilenet_v1")             # has dw + pw
    assert len(shapes) == 27
    entries = autotune._load_packaged("interpret")
    assert entries, "packaged interpret.json missing or stale schema"
    for s in shapes:
        key = autotune.conv_key(s["B"], s["H"], s["W"], s["C"], s["K"],
                                s["Cout"], stride=s["stride"],
                                padding=s["padding"], groups=s["groups"],
                                backend="interpret")
        assert key in entries, f"warm tier misses {key}"
        assert autotune.lookup(key) == entries[key]["config"]


# --------------------------------------------------- concurrent-writer merge


def test_record_merges_concurrent_writers():
    """Two processes tuning different layers interleave: A and B both
    snapshot an empty table; A lands its entry; B's record() must re-read
    and merge, not clobber A's entry with its own stale snapshot."""
    key_a = autotune.conv_key(*ARGS, backend="cpu")
    key_b = autotune.attention_key(1, 1, 4096, 8, 2, 64, backend="cpu")
    autotune._load()              # process B's in-memory snapshot: empty
    # process A (simulated externally) lands its entry on disk
    with open(autotune.table_path(), "w") as f:
        json.dump({"version": autotune.SCHEMA_VERSION,
                   "entries": {key_a: {"config": dict(block_cin=8),
                                       "us": 5.0}}}, f)
    autotune.record(key_b, dict(block_q=8, block_k=256), 7.0)  # process B
    disk = json.load(open(autotune.table_path()))
    assert disk["entries"][key_a]["config"] == dict(block_cin=8)  # survived
    assert disk["entries"][key_b]["config"] == dict(block_q=8, block_k=256)
    # and the reverse conflict: B's own fresh measurement wins its key
    autotune.record(key_a, dict(block_cin=4), 1.0)
    disk = json.load(open(autotune.table_path()))
    assert disk["entries"][key_a]["config"] == dict(block_cin=4)
    assert disk["entries"][key_b]["config"] == dict(block_q=8, block_k=256)


# ------------------------------------------------------------ reps validation


def test_autotune_reps_zero_raises():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 4, 4, 2)).astype(np.float32))
    qt = quantize_tensor(jnp.asarray(
        rng.normal(size=(3, 3, 2, 4)).astype(np.float32)))
    with pytest.raises(ValueError, match="reps >= 1"):
        autotune.autotune_conv2d(x, qt.packed, qt.scale, qt.cfg,
                                 interpret=True, reps=0)
    q = jnp.asarray(rng.normal(size=(1, 4, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 4, 2, 8)), jnp.float32)
    with pytest.raises(ValueError, match="reps >= 1"):
        autotune.autotune_attention(q, k, k, interpret=True, reps=-1)


# ------------------------------------------------- partial-config dispatch


def test_partial_conv_config_fills_from_table(monkeypatch):
    """`ops.conv2d` with only some `ConvConfig` fields set fills the rest
    per-field from the table — the documented contract a partial config
    used to silently bypass."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(1, 8, 8, 5)).astype(np.float32))
    qt = quantize_tensor(jnp.asarray(
        rng.normal(size=(3, 3, 5, 7)).astype(np.float32)))
    key = autotune.conv_key(*ARGS, cfg=qt.cfg, backend="interpret")
    autotune.record(key, dict(block_cin=4, block_cout=8, rows_per_tile=2,
                              batch_per_tile=1, lane_pack=1), 9.0)
    seen = {}
    real = ops.log_conv2d_fused_pallas

    def spy(*a, **kw):
        seen.update(kw)
        return real(*a, **kw)

    monkeypatch.setattr(ops, "log_conv2d_fused_pallas", spy)
    y = ops.conv2d(x, qt, impl="pallas", interpret=True,
                   config=ops.ConvConfig(rows_per_tile=4))
    assert seen["rows_per_tile"] == 4          # explicit field kept
    assert seen["block_cin"] == 4              # … the rest from the table
    assert seen["block_cout"] == 8
    assert seen["batch_per_tile"] == 1
    y_ref = ops.conv2d(x, qt, impl="ref")
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(y_ref),
        atol=1e-4 * float(jnp.max(jnp.abs(y_ref)) + 1))
    # a fully-pinned config consults no table at all
    c0 = _lookup_counts()
    seen.clear()
    ops.conv2d(x, qt, impl="pallas", interpret=True,
               config=dict(block_cin=8, block_cout=8, rows_per_tile=4,
                           batch_per_tile=1, lane_pack=1))
    assert _lookup_counts() == c0
    assert seen["block_cin"] == 8


# --------------------------------------------- suppressed-autotune warnings


@pytest.fixture()
def _fresh_warnings(monkeypatch):
    monkeypatch.setattr(ops, "_WARNED_ONCE", set())


def test_autotune_suppressed_by_conv_config_warns_once(_fresh_warnings):
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(1, 8, 8, 5)).astype(np.float32))
    qt = quantize_tensor(jnp.asarray(
        rng.normal(size=(3, 3, 5, 7)).astype(np.float32)))
    cfg = dict(block_cin=8, block_cout=8, rows_per_tile=4,
               batch_per_tile=1, lane_pack=1)
    with pytest.warns(UserWarning, match="autotune=True is a no-op"):
        ops.conv2d(x, qt, impl="pallas", interpret=True, config=cfg,
                   autotune=True)
    assert not autotune._load()["entries"]      # and no sweep ran
    with warnings.catch_warnings():
        warnings.simplefilter("error")          # one-shot: second call quiet
        ops.conv2d(x, qt, impl="pallas", interpret=True, config=cfg,
                   autotune=True)


def test_autotune_suppressed_by_attention_config_warns(_fresh_warnings):
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(1, 8, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 8, 2, 8)), jnp.float32)
    with pytest.warns(UserWarning, match="autotune=True is a no-op"):
        ops.attention(q, k, k, impl="pallas", interpret=True, autotune=True,
                      config=ops.AttentionConfig(block_q=8, block_k=8))
    assert not autotune._load()["entries"]      # and no sweep ran


def test_autotune_unpacks_baked_lane_layout_with_warning(_fresh_warnings):
    from repro.serving.quantize import quantize_cnn_params
    rng = np.random.default_rng(6)
    C = 4
    x = jnp.asarray(rng.normal(size=(1, 4, 4, C)).astype(np.float32))
    params = {"w": jnp.asarray(rng.normal(size=(3, 3, 1, C))
                               .astype(np.float32))}
    qp = quantize_cnn_params(params, conv_layout="lane_packed")
    assert qp["w"].layout == "lane_packed"
    with pytest.warns(UserWarning, match="unpacked the baked"):
        y = ops.conv2d(x, qp["w"], impl="pallas", interpret=True,
                       groups=C, autotune=True)
    y_ref = ops.conv2d(x, qp["w"], impl="blockwise", groups=C)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(y_ref),
        atol=1e-4 * float(jnp.max(jnp.abs(y_ref)) + 1))
    assert autotune._load()["entries"]          # the sweep did run


def test_candidates_fit_vmem_budget_and_dedupe():
    cands = autotune.candidate_configs(*ARGS)
    assert cands, "no candidates for a tiny layer"
    seen = set()
    for c in cands:
        assert autotune.estimate_vmem_bytes(
            *ARGS, **c) <= autotune.VMEM_BUDGET_BYTES
        sig = tuple(sorted(c.items(), key=str))
        assert sig not in seen
        seen.add(sig)


def test_key_namespaces_distinct_per_op():
    ck = autotune.conv_key(*ARGS, backend="cpu")
    ak = autotune.attention_key(1, 8, 8, 5, 1, 7, backend="cpu")
    assert ck.startswith("conv2d|") and ak.startswith("attention|")
    assert ck != ak


def test_attention_key_carries_shape_mask_backend():
    args = (2, 16, 128, 8, 2, 64)
    k1 = autotune.attention_key(*args, backend="cpu")
    assert autotune.attention_key(*args, backend="cpu") == k1
    for other in (autotune.attention_key(2, 16, 128, 8, 4, 64,
                                         backend="cpu"),
                  autotune.attention_key(2, 16, 256, 8, 2, 64,
                                         backend="cpu"),
                  autotune.attention_key(*args, causal=False, backend="cpu"),
                  autotune.attention_key(*args, window=64, backend="cpu"),
                  autotune.attention_key(*args, backend="tpu")):
        assert other != k1


def test_attention_record_lookup_roundtrip_persists():
    key = autotune.attention_key(1, 1, 4096, 8, 2, 64, backend="interpret")
    cfg = dict(block_q=8, block_k=256)
    autotune.record(key, cfg, 42.0)
    assert autotune.lookup(key) == cfg
    autotune.reset_cache()          # force re-read from disk
    assert autotune.lookup(key) == cfg
    table = json.load(open(autotune.table_path()))
    assert table["version"] == autotune.SCHEMA_VERSION
    # conv entries coexist in the same table file
    ck = autotune.conv_key(*ARGS, backend="cpu")
    autotune.record(ck, dict(block_cin=4), 1.0)
    assert autotune.lookup(key) == cfg and autotune.lookup(ck) is not None


def test_attention_candidates_fit_vmem_budget_and_dedupe():
    args = (1, 1, 4096, 8, 2, 64)
    cands = autotune.attention_candidate_configs(*args)
    assert cands
    seen = set()
    for c in cands:
        assert autotune.estimate_attention_vmem_bytes(
            *args, **c) <= autotune.VMEM_BUDGET_BYTES
        sig = (c["block_q"], c["block_k"])
        assert sig not in seen
        seen.add(sig)
    # decode shape: folded rep·Tq rows keep block_q small
    assert all(c["block_q"] <= 8 for c in cands)


def test_autotune_attention_persists_winner_and_is_picked_up():
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.normal(size=(1, 16, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 16, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 16, 2, 16)), jnp.float32)
    winner = autotune.autotune_attention(q, k, v, interpret=True, reps=1,
                                         max_candidates=2)
    key = autotune.attention_key(1, 16, 16, 4, 2, 16, backend="interpret")
    assert autotune.lookup(key) == winner
    # subsequent plain pallas calls pick the persisted winner up
    y = ops.attention(q, k, v, impl="pallas", interpret=True)
    from repro.kernels.ref import ref_attention
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref_attention(q, k, v)),
                               rtol=2e-4, atol=2e-4)


def test_autotune_persists_winner_and_matches_ref():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(1, 8, 8, 5)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 3, 5, 7)).astype(np.float32))
    qt = quantize_tensor(w)
    y_tuned = ops.conv2d(x, qt, impl="pallas", interpret=True, autotune=True)
    y_ref = ops.conv2d(x, qt, impl="ref")
    np.testing.assert_allclose(np.asarray(y_tuned), np.asarray(y_ref),
                               atol=1e-4 * float(jnp.max(jnp.abs(y_ref)) + 1))
    key = autotune.conv_key(*ARGS, cfg=qt.cfg, backend="interpret")
    winner = autotune.lookup(key)
    assert winner is not None
    # subsequent plain calls pick the persisted winner up transparently
    y_again = ops.conv2d(x, qt, impl="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(y_again), np.asarray(y_tuned))
