"""The op-keyed block-size autotuner: table persistence, keying (conv2d
and attention namespaces), invalidation, candidate filtering, and
numerics of tuned configs."""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops
from repro.core.logquant import LogQuantConfig, quantize_tensor

SHAPE = dict(B=1, H=8, W=8, C=5, K=3, Cout=7)
ARGS = (1, 8, 8, 5, 3, 7)


@pytest.fixture(autouse=True)
def _isolated_table(tmp_path, monkeypatch):
    """Every test gets its own on-disk table; the module cache is reset so
    nothing leaks between tests (or into the user's real cache dir)."""
    monkeypatch.setenv("REPRO_AUTOTUNE_PATH", str(tmp_path / "table.json"))
    autotune.reset_cache()
    yield
    autotune.reset_cache()


def test_key_carries_shape_stride_groups_backend():
    k1 = autotune.conv_key(*ARGS, backend="cpu")
    assert autotune.conv_key(*ARGS, backend="cpu") == k1  # deterministic
    for other in (autotune.conv_key(1, 8, 8, 5, 3, 9, backend="cpu"),
                  autotune.conv_key(*ARGS, stride=2, backend="cpu"),
                  autotune.conv_key(*ARGS, padding="VALID", backend="cpu"),
                  autotune.conv_key(*ARGS, backend="tpu"),
                  autotune.conv_key(*ARGS, cfg=LogQuantConfig(bits=4),
                                    backend="cpu")):
        assert other != k1


def test_record_lookup_roundtrip_persists():
    key = autotune.conv_key(*ARGS, backend="cpu")
    cfg = dict(block_cin=4, block_cout=8, rows_per_tile=4, batch_per_tile=1)
    autotune.record(key, cfg, 12.5)
    assert autotune.lookup(key) == cfg
    autotune.reset_cache()          # force re-read from disk
    assert autotune.lookup(key) == cfg
    table = json.load(open(autotune.table_path()))
    assert table["version"] == autotune.SCHEMA_VERSION
    assert table["entries"][key]["us"] == 12.5


def test_stale_schema_version_invalidates_table():
    key = autotune.conv_key(*ARGS, backend="cpu")
    autotune.record(key, dict(block_cin=4), 1.0)
    autotune.reset_cache()
    path = autotune.table_path()
    table = json.load(open(path))
    table["version"] = autotune.SCHEMA_VERSION - 1
    json.dump(table, open(path, "w"))
    assert autotune.lookup(key) is None  # stale entries are not served


def test_corrupt_table_is_ignored():
    with open(autotune.table_path(), "w") as f:
        f.write("{not json")
    assert autotune.lookup("anything") is None
    autotune.record("k", dict(block_cin=4), 1.0)  # and is recoverable
    autotune.reset_cache()
    assert autotune.lookup("k") == dict(block_cin=4)


def test_candidates_fit_vmem_budget_and_dedupe():
    cands = autotune.candidate_configs(*ARGS)
    assert cands, "no candidates for a tiny layer"
    seen = set()
    for c in cands:
        assert autotune.estimate_vmem_bytes(
            *ARGS, **c) <= autotune.VMEM_BUDGET_BYTES
        sig = tuple(sorted(c.items(), key=str))
        assert sig not in seen
        seen.add(sig)


def test_key_namespaces_distinct_per_op():
    ck = autotune.conv_key(*ARGS, backend="cpu")
    ak = autotune.attention_key(1, 8, 8, 5, 1, 7, backend="cpu")
    assert ck.startswith("conv2d|") and ak.startswith("attention|")
    assert ck != ak


def test_attention_key_carries_shape_mask_backend():
    args = (2, 16, 128, 8, 2, 64)
    k1 = autotune.attention_key(*args, backend="cpu")
    assert autotune.attention_key(*args, backend="cpu") == k1
    for other in (autotune.attention_key(2, 16, 128, 8, 4, 64,
                                         backend="cpu"),
                  autotune.attention_key(2, 16, 256, 8, 2, 64,
                                         backend="cpu"),
                  autotune.attention_key(*args, causal=False, backend="cpu"),
                  autotune.attention_key(*args, window=64, backend="cpu"),
                  autotune.attention_key(*args, backend="tpu")):
        assert other != k1


def test_attention_record_lookup_roundtrip_persists():
    key = autotune.attention_key(1, 1, 4096, 8, 2, 64, backend="interpret")
    cfg = dict(block_q=8, block_k=256)
    autotune.record(key, cfg, 42.0)
    assert autotune.lookup(key) == cfg
    autotune.reset_cache()          # force re-read from disk
    assert autotune.lookup(key) == cfg
    table = json.load(open(autotune.table_path()))
    assert table["version"] == autotune.SCHEMA_VERSION
    # conv entries coexist in the same table file
    ck = autotune.conv_key(*ARGS, backend="cpu")
    autotune.record(ck, dict(block_cin=4), 1.0)
    assert autotune.lookup(key) == cfg and autotune.lookup(ck) is not None


def test_attention_candidates_fit_vmem_budget_and_dedupe():
    args = (1, 1, 4096, 8, 2, 64)
    cands = autotune.attention_candidate_configs(*args)
    assert cands
    seen = set()
    for c in cands:
        assert autotune.estimate_attention_vmem_bytes(
            *args, **c) <= autotune.VMEM_BUDGET_BYTES
        sig = (c["block_q"], c["block_k"])
        assert sig not in seen
        seen.add(sig)
    # decode shape: folded rep·Tq rows keep block_q small
    assert all(c["block_q"] <= 8 for c in cands)


def test_autotune_attention_persists_winner_and_is_picked_up():
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.normal(size=(1, 16, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 16, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 16, 2, 16)), jnp.float32)
    winner = autotune.autotune_attention(q, k, v, interpret=True, reps=1,
                                         max_candidates=2)
    key = autotune.attention_key(1, 16, 16, 4, 2, 16, backend="interpret")
    assert autotune.lookup(key) == winner
    # subsequent plain pallas calls pick the persisted winner up
    y = ops.attention(q, k, v, impl="pallas", interpret=True)
    from repro.kernels.ref import ref_attention
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref_attention(q, k, v)),
                               rtol=2e-4, atol=2e-4)


def test_autotune_persists_winner_and_matches_ref():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(1, 8, 8, 5)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 3, 5, 7)).astype(np.float32))
    qt = quantize_tensor(w)
    y_tuned = ops.conv2d(x, qt, impl="pallas", interpret=True, autotune=True)
    y_ref = ops.conv2d(x, qt, impl="ref")
    np.testing.assert_allclose(np.asarray(y_tuned), np.asarray(y_ref),
                               atol=1e-4 * float(jnp.max(jnp.abs(y_ref)) + 1))
    key = autotune.conv_key(*ARGS, cfg=qt.cfg, backend="interpret")
    winner = autotune.lookup(key)
    assert winner is not None
    # subsequent plain calls pick the persisted winner up transparently
    y_again = ops.conv2d(x, qt, impl="pallas", interpret=True)
    np.testing.assert_array_equal(np.asarray(y_again), np.asarray(y_tuned))
