"""Smoke + numerics tests for the CNN substrate (paper's own workload)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.neuromax_cnn import CONFIG
from repro.models.cnn import CNNS, cnn_loss, make_cnn

RED = CONFIG.reduced()


@pytest.mark.parametrize("name", sorted(CNNS))
def test_cnn_forward_shapes_and_finiteness(name):
    key = jax.random.PRNGKey(0)
    params, apply_fn = make_cnn(name, key, n_classes=RED.n_classes,
                                width_mult=RED.width_mult)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, RED.img, RED.img, 3))
    logits = apply_fn(params, x)
    assert logits.shape == (2, RED.n_classes)
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("name", ["vgg16", "mobilenet_v1"])
def test_cnn_logq6_close_to_fp(name):
    """Fake log-quant numerics stay within the base-√2 error envelope."""
    key = jax.random.PRNGKey(2)
    params, apply_fp = make_cnn(name, key, n_classes=10, width_mult=0.25)
    _, apply_q = make_cnn(name, key, n_classes=10, width_mult=0.25,
                          quant="logq6")
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 32, 32, 3))
    lf = np.asarray(apply_fp(params, x))
    lq = np.asarray(apply_q(params, x))
    assert np.all(np.isfinite(lq))
    # logits correlate strongly (quant noise, not garbage)
    c = np.corrcoef(lf.ravel(), lq.ravel())[0, 1]
    assert c > 0.9


@pytest.mark.parametrize("name", sorted(CNNS))
def test_cnn_conv_impl_blockwise_matches_fake_quant(name):
    """conv_impl routes convs through kernels/ops.conv2d on packed codes;
    same quantization grid as fake-quant ⇒ logits match within quant/conv
    float tolerance."""
    key = jax.random.PRNGKey(6)
    params, apply_fq = make_cnn(name, key, n_classes=10, width_mult=0.25,
                                quant="logq6")
    _, apply_bw = make_cnn(name, key, n_classes=10, width_mult=0.25,
                           quant="logq6", conv_impl="blockwise")
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 32, 32, 3))
    lf = np.asarray(apply_fq(params, x))
    lb = np.asarray(apply_bw(params, x))
    np.testing.assert_allclose(lb, lf, atol=1e-4 * (np.abs(lf).max() + 1))


def test_cnn_packed_at_load_matches_on_the_fly():
    """serving.quantize_cnn_params packs once; forward equals per-call
    packing and most parameter bytes become int8 codes."""
    from repro.serving.quantize import (quantize_cnn_params,
                                        quantized_fraction)
    key = jax.random.PRNGKey(8)
    params, apply_bw = make_cnn("mobilenet_v1", key, n_classes=10,
                                width_mult=0.25, quant="logq6",
                                conv_impl="blockwise")
    qparams = quantize_cnn_params(params)
    assert quantized_fraction(qparams) > 0.5
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 32, 32, 3))
    np.testing.assert_array_equal(np.asarray(apply_bw(qparams, x)),
                                  np.asarray(apply_bw(params, x)))


def test_cnn_conv_taps_layout_matches_hwio():
    """conv_layout="conv_taps" pre-reshapes packed codes to the fused
    kernel's tap-major HBM layout at load time — same numerics, and
    dequantize restores the original [K, K, Cin_g, Cout] kernel."""
    from repro.serving.quantize import quantize_cnn_params
    key = jax.random.PRNGKey(10)
    params, apply_bw = make_cnn("mobilenet_v1", key, n_classes=10,
                                width_mult=0.25, quant="logq6",
                                conv_impl="blockwise")
    q_hwio = quantize_cnn_params(params)
    q_taps = quantize_cnn_params(params, conv_layout="conv_taps")
    stem = q_taps["stem"]["w"]
    assert stem.layout == "conv_taps" and stem.packed.ndim == 3
    np.testing.assert_array_equal(
        np.asarray(stem.dequantize(jnp.float32)),
        np.asarray(q_hwio["stem"]["w"].dequantize(jnp.float32)))
    x = jax.random.normal(jax.random.PRNGKey(11), (2, 32, 32, 3))
    np.testing.assert_array_equal(np.asarray(apply_bw(q_taps, x)),
                                  np.asarray(apply_bw(q_hwio, x)))


def test_cnn_conv_impl_fused_pallas_matches_blockwise():
    """The model zoo's conv_impl="pallas" routes through the fused
    implicit-im2col kernel (interpret mode on CPU) — logits match the
    blockwise lowering."""
    key = jax.random.PRNGKey(12)
    params, apply_bw = make_cnn("vgg16", key, n_classes=10, width_mult=0.25,
                                quant="logq6", conv_impl="blockwise")
    _, apply_fz = make_cnn("vgg16", key, n_classes=10, width_mult=0.25,
                           quant="logq6", conv_impl="pallas", interpret=True)
    x = jax.random.normal(jax.random.PRNGKey(13), (1, 16, 16, 3))
    lb = np.asarray(apply_bw(params, x))
    lz = np.asarray(apply_fz(params, x))
    np.testing.assert_allclose(lz, lb, atol=1e-3 * (np.abs(lb).max() + 1))


def test_cnn_train_step_reduces_loss():
    key = jax.random.PRNGKey(4)
    params, apply_fn = make_cnn("squeezenet", key, n_classes=4,
                                width_mult=0.25, quant="logq6")
    x = jax.random.normal(jax.random.PRNGKey(5), (8, 32, 32, 3))
    y = jnp.arange(8) % 4
    batch = {"images": x, "labels": y}

    @jax.jit
    def step(p):
        (loss, _), g = jax.value_and_grad(
            lambda pp: cnn_loss(apply_fn, pp, batch), has_aux=True)(p)
        return loss, jax.tree.map(lambda a, b: a - 0.05 * b, p, g)

    loss0, params = step(params)
    for _ in range(10):
        loss, params = step(params)
    assert float(loss) < float(loss0)
    assert np.isfinite(float(loss))
