"""Cross-checks for the unified log-domain conv2d stack.

Three tiers, one contract:
  * `kernels/log_conv2d.py` pallas (interpret=True on CPU) vs blockwise vs
    the full-materialisation ref — allclose on every shape class the models
    use (3×3, stride-2, depthwise, grouped, 1×1, K=5);
  * kernel vs the vectorized `core/pe_grid.py` log-mode hardware oracle —
    same codes, same LogQuantConfig, tolerance = the per-product fixed-point
    LUT rounding;
  * `models/cnn.py` conv_impl="blockwise" vs the old fake-quant lax.conv
    path — identical quantization grid, so bit-equal logits;
  * vectorized PE grid vs the per-scalar seed path — bit-identical psums,
    ≥20× faster on a 16×16×6→4 layer.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.logquant import (LogQuantConfig, log_dequantize, log_quantize,
                                 quantize_tensor)
from repro.core.pe_grid import PEGrid
from repro.kernels import ops

# ---------------------------------------------------------------------------
# pallas ↔ blockwise ↔ ref
# ---------------------------------------------------------------------------

SHAPES = [  # B, H, W, C, K, P, stride, padding, groups
    (2, 8, 8, 5, 3, 7, 1, "SAME", 1),
    (1, 9, 7, 4, 3, 6, 2, "SAME", 1),
    (2, 8, 8, 6, 3, 6, 1, "VALID", 6),    # depthwise
    (1, 10, 10, 4, 1, 8, 1, "VALID", 1),  # 1x1 (pwconv)
    (1, 8, 8, 6, 3, 4, 2, "SAME", 2),     # grouped, stride 2
    (1, 8, 8, 3, 5, 4, 2, 2, 1),          # K=5, int padding (ResNet stem)
    # normalize_padding edge cases, through every impl:
    (1, 8, 8, 3, 3, 5, 1, ((1, 2), (0, 1)), 1),  # explicit asymmetric pairs
    (1, 10, 10, 4, 3, 6, 2, "SAME", 1),   # SAME, even input, stride 2
    (1, 9, 9, 4, 3, 5, 2, "VALID", 1),    # VALID where Ho/Wo round down
]


@pytest.mark.parametrize("B,H,W,C,K,P,stride,padding,groups", SHAPES)
def test_conv2d_impls_agree(B, H, W, C, K, P, stride, padding, groups):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(B, H, W, C)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(K, K, C // groups, P)).astype(np.float32))
    qt = quantize_tensor(w)
    kw = dict(stride=stride, padding=padding, groups=groups)
    y_ref = ops.conv2d(x, qt, impl="ref", **kw)
    y_bw = ops.conv2d(x, qt, impl="blockwise", **kw)
    y_im = ops.conv2d(x, qt, impl="pallas_im2col", interpret=True, **kw)
    y_fz = ops.conv2d(x, qt, impl="pallas", interpret=True, **kw)
    assert y_ref.shape == y_bw.shape == y_im.shape == y_fz.shape
    tol = 1e-4 * float(jnp.max(jnp.abs(y_ref)) + 1)
    for y in (y_bw, y_im, y_fz):
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=tol)
    # acceptance: fused ≡ blockwise within 1e-3 max-abs
    assert float(jnp.max(jnp.abs(y_fz - y_bw))) < 1e-3


@pytest.mark.parametrize("config", [
    dict(rows_per_tile=2),                      # row tiles + halo duplication
    dict(rows_per_tile=3, batch_per_tile=1),    # non-dividing row tile
    dict(rows_per_tile=1, batch_per_tile=3),    # batch-stationary weights
    dict(block_cin=4, block_cout=4),            # multi-block reduction
])
def test_fused_tiling_configs_agree(config):
    """Every (rows_per_tile, batch_per_tile, block) tiling is numerically
    the same conv — the autotuner may pick any of them."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(3, 11, 9, 6)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 3, 6, 8)).astype(np.float32))
    qt = quantize_tensor(w)
    y_ref = ops.conv2d(x, qt, impl="ref", stride=2)
    y = ops.conv2d(x, qt, impl="pallas", interpret=True, stride=2,
                   config=dict(config))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-4 * float(jnp.max(jnp.abs(y_ref)) + 1))


# ---------------------------------------------------------------------------
# lane-packed grouped/depthwise layout
# ---------------------------------------------------------------------------

LANE_SHAPES = [  # B, H, W, C, K, P, stride, padding, groups
    (1, 8, 8, 6, 3, 6, 1, "SAME", 6),      # depthwise, multiplier 1
    (1, 8, 8, 6, 3, 12, 1, "SAME", 6),     # depthwise, Cout = Cin * 2
    (1, 9, 7, 12, 3, 8, 2, "SAME", 4),     # cin_g=3: no power of 2, 128 % 3 ≠ 0
    (1, 8, 8, 8, 3, 8, 1, "VALID", 4),     # cin_g=2
    (2, 8, 8, 16, 5, 8, 2, 2, 4),          # cin_g=4, K=5, int padding
    (1, 8, 8, 4, 3, 8, 1, ((1, 2), (0, 1)), 4),  # asymmetric pads, depthwise
]


@pytest.mark.parametrize("B,H,W,C,K,P,stride,padding,groups", LANE_SHAPES)
def test_lane_packed_agrees_with_padded_and_lax(B, H, W, C, K, P, stride,
                                                padding, groups):
    """Lane-packed vs forced-padded vs the decode+lax.conv fallback across
    a stride/padding sweep.  The packed kernel's out-of-group taps are
    exact zeros, so packed and padded run the same per-group sums — any
    residual is f32 contraction-order noise, bounded far below the
    quantization error the `tol` of `test_conv2d_impls_agree` allows."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(B, H, W, C)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(K, K, C // groups, P)).astype(np.float32))
    qt = quantize_tensor(w)
    kw = dict(stride=stride, padding=padding, groups=groups)
    y_packed = ops.conv2d(x, qt, impl="pallas", interpret=True,
                          config=dict(lane_pack=None), **kw)
    y_padded = ops.conv2d(x, qt, impl="pallas", interpret=True,
                          config=dict(lane_pack=1), **kw)
    # the packing must actually engage for these narrow-group shapes
    from repro.kernels.log_conv2d import lane_pack_geometry
    assert lane_pack_geometry(groups, C // groups)["g_b"] > 1
    eps = 16 * np.finfo(np.float32).eps * float(jnp.max(jnp.abs(y_padded)) + 1)
    np.testing.assert_allclose(np.asarray(y_packed), np.asarray(y_padded),
                               atol=eps)
    # vs the lax.conv fallback on the decoded weights (shared quant grid)
    y_bw = ops.conv2d(x, qt, impl="blockwise", **kw)
    assert y_packed.shape == y_bw.shape
    tol = 1e-4 * float(jnp.max(jnp.abs(y_bw)) + 1)
    np.testing.assert_allclose(np.asarray(y_packed), np.asarray(y_bw),
                               atol=tol)


def test_lane_pack_codes_roundtrip_exact():
    """pack → unpack is the identity on the raw int8 codes."""
    from repro.kernels.log_conv2d import (lane_pack_codes, lane_pack_geometry,
                                          lane_unpack_codes)
    rng = np.random.default_rng(6)
    for C, groups, P, K in ((6, 6, 6, 3), (12, 4, 8, 3), (16, 4, 8, 5)):
        cin_g = C // groups
        w = jnp.asarray(rng.normal(size=(K, K, cin_g, P)).astype(np.float32))
        qt = quantize_tensor(w)
        lp = lane_pack_geometry(groups, cin_g)
        codes = lane_pack_codes(qt.packed, groups, lp["g_b"], lp["cin_lane"])
        assert codes.shape == (lp["n_sb"], K * K,
                               lp["g_b"] * lp["cin_lane"], P // groups)
        back = lane_unpack_codes(codes, qt.packed.shape, groups, lp["g_b"],
                                 lp["cin_lane"])
        np.testing.assert_array_equal(np.asarray(back), np.asarray(qt.packed))


def test_lane_packed_quantized_tensor_serving_path():
    """`quantize_cnn_params(conv_layout="lane_packed")` bakes depthwise
    kernels into the superblock layout; `ops.conv2d` rides it prepacked
    (bit-identical to packing on the fly) and unpacks gracefully when the
    call disagrees with the baked map."""
    from repro.serving.quantize import quantize_cnn_params
    rng = np.random.default_rng(7)
    C = 12
    w = jnp.asarray(rng.normal(size=(3, 3, 1, C)).astype(np.float32))
    x = jnp.asarray(rng.normal(size=(1, 8, 8, C)).astype(np.float32))
    params = {"conv": {"w": w, "b": jnp.zeros(C)}}
    qp = quantize_cnn_params(params, conv_layout="lane_packed")
    qt_lp = qp["conv"]["w"]
    assert qt_lp.layout == "lane_packed"
    g_b, cin_lane, meta_groups = qt_lp.layout_meta
    assert meta_groups == C and g_b > 1
    # dequantize round-trips through the packed layout exactly
    qt = quantize_tensor(w)
    np.testing.assert_array_equal(np.asarray(qt_lp.dequantize(jnp.float32)),
                                  np.asarray(qt.dequantize(jnp.float32)))
    # prepacked fast path ≡ on-the-fly packing, bit for bit
    y_fly = ops.conv2d(x, qt, impl="pallas", interpret=True, groups=C)
    y_pre = ops.conv2d(x, qt_lp, impl="pallas", interpret=True, groups=C)
    np.testing.assert_array_equal(np.asarray(y_pre), np.asarray(y_fly))
    # graceful unpack: non-pallas impl and a conflicting explicit lane_pack
    y_bw = ops.conv2d(x, qt, impl="blockwise", groups=C)
    np.testing.assert_array_equal(
        np.asarray(ops.conv2d(x, qt_lp, impl="blockwise", groups=C)),
        np.asarray(y_bw))
    y_off = ops.conv2d(x, qt_lp, impl="pallas", interpret=True, groups=C,
                       config=ops.ConvConfig(lane_pack=1))
    tol = 1e-4 * float(jnp.max(jnp.abs(y_bw)) + 1)
    np.testing.assert_allclose(np.asarray(y_off), np.asarray(y_bw), atol=tol)
    # non-depthwise leaves fall back to conv_taps
    qp2 = quantize_cnn_params({"c": {"w": jnp.asarray(
        rng.normal(size=(3, 3, 4, 8)).astype(np.float32))}},
        conv_layout="lane_packed")
    assert qp2["c"]["w"].layout == "conv_taps"


def test_lane_pack_autotune_candidates_and_traffic():
    """Grouped shapes tune over both packed and padded variants, and the
    analytic model shows the recovered density at the 128-lane width."""
    from repro.kernels import autotune
    from repro.kernels.log_conv2d import conv_traffic_bytes
    cands = autotune.candidate_configs(1, 8, 8, 32, 3, 32, groups=32)
    assert {c.get("lane_pack") for c in cands} >= {None, 1}
    # dense shapes don't get lane variants (packing can't engage)
    dense = autotune.candidate_configs(1, 8, 8, 128, 3, 128, groups=1)
    assert {c.get("lane_pack") for c in dense} == {None}
    kw = dict(stride=1, padding="SAME", groups=32)
    packed = conv_traffic_bytes("pallas", 1, 8, 8, 32, 3, 32, lanes=128,
                                config=dict(lane_pack=None), **kw)
    padded = conv_traffic_bytes("pallas", 1, 8, 8, 32, 3, 32, lanes=128,
                                config=dict(lane_pack=1), **kw)
    assert padded["act_w"] / packed["act_w"] >= 4.0
    assert packed["lane_density"] > padded["lane_density"]
    # lanes=1 (pure byte count) is unchanged by packing: same codes moved
    b_packed = conv_traffic_bytes("pallas", 1, 8, 8, 32, 3, 32, lanes=1,
                                  config=dict(lane_pack=None), **kw)
    b_padded = conv_traffic_bytes("pallas", 1, 8, 8, 32, 3, 32, lanes=1,
                                  config=dict(lane_pack=1), **kw)
    assert b_packed["w"] == b_padded["w"]


def test_conv2d_accepts_unpacked_weights():
    """A plain float kernel is packed on the fly — same result as packing."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 6, 6, 3)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(3, 3, 3, 4)).astype(np.float32))
    y1 = ops.conv2d(x, w, impl="blockwise")
    y2 = ops.conv2d(x, quantize_tensor(w), impl="blockwise")
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


# ---------------------------------------------------------------------------
# kernel ↔ PE-grid hardware oracle (log mode, shared quant grid)
# ---------------------------------------------------------------------------

CFG = LogQuantConfig(per_channel=False)


def _deq(t):
    packed, scale = log_quantize(jnp.asarray(t), CFG)
    return np.asarray(log_dequantize(packed, scale, CFG))


def _grid_tol(y):
    # per-product LUT rounding at out_frac_bits=16, accumulated over taps
    return 5e-3 * float(np.abs(y).max() + 1)


@pytest.mark.parametrize("stride", [1, 2])
def test_kernel_matches_pe_grid_3x3(stride):
    """3×3 (and stride-2) conv: Pallas/blockwise vs the grid's adder nets."""
    rng = np.random.default_rng(11)
    x = np.abs(rng.normal(size=(12, 10, 6))).astype(np.float32)  # post-ReLU
    w = rng.normal(size=(3, 3, 6, 4)).astype(np.float32)
    grid = PEGrid(mode="log", quant_cfg=CFG, out_frac_bits=16)
    y_grid, stats = grid.conv2d(x, w, stride=stride)
    assert stats.cycles > 0

    qt = quantize_tensor(jnp.asarray(w), CFG)
    xd = jnp.asarray(_deq(x))[None]  # the codes the grid's threads see
    for impl, kw in (("blockwise", {}), ("pallas", {"interpret": True}),
                    ("pallas_im2col", {"interpret": True})):
        y_k = ops.conv2d(xd, qt, stride=stride, padding="VALID", impl=impl,
                         **kw)
        np.testing.assert_allclose(np.asarray(y_k[0]), y_grid,
                                   atol=_grid_tol(y_grid))


def test_kernel_matches_pe_grid_depthwise():
    """dwconv (groups=C): matrix-per-channel grid mode vs block-diag kernel."""
    rng = np.random.default_rng(12)
    C = 5
    x = np.abs(rng.normal(size=(10, 9, C))).astype(np.float32)
    w = rng.normal(size=(3, 3, C)).astype(np.float32)
    grid = PEGrid(mode="log", quant_cfg=CFG, out_frac_bits=16)
    y_grid, _ = grid.conv2d_depthwise(x, w)

    qt = quantize_tensor(jnp.asarray(w)[:, :, None, :], CFG)  # [3,3,1,C]
    xd = jnp.asarray(_deq(x))[None]
    for impl, kw in (("blockwise", {}), ("pallas", {"interpret": True}),
                    ("pallas_im2col", {"interpret": True})):
        y_k = ops.conv2d(xd, qt, padding="VALID", groups=C, impl=impl, **kw)
        np.testing.assert_allclose(np.asarray(y_k[0]), y_grid,
                                   atol=_grid_tol(y_grid))


def test_kernel_matches_pe_grid_1x1():
    """pwconv: §5.2 channel-parallel grid mapping vs the K=1 kernel."""
    rng = np.random.default_rng(13)
    x = np.abs(rng.normal(size=(9, 8, 20))).astype(np.float32)
    w = rng.normal(size=(20, 6)).astype(np.float32)
    grid = PEGrid(mode="log", quant_cfg=CFG, out_frac_bits=16)
    y_grid, _ = grid.conv2d_1x1(x, w)

    qt = quantize_tensor(jnp.asarray(w)[None, None], CFG)  # [1,1,20,6]
    xd = jnp.asarray(_deq(x))[None]
    for impl, kw in (("blockwise", {}), ("pallas", {"interpret": True}),
                    ("pallas_im2col", {"interpret": True})):
        y_k = ops.conv2d(xd, qt, padding="VALID", impl=impl, **kw)
        np.testing.assert_allclose(np.asarray(y_k[0]), y_grid,
                                   atol=_grid_tol(y_grid))


def test_pe_grid_depthwise_float_exact():
    """Float-mode dwconv isolates the wiring — bit-exact vs lax grouped conv."""
    rng = np.random.default_rng(14)
    for stride in (1, 2):
        x = rng.normal(size=(10, 9, 5)).astype(np.float32)
        w = rng.normal(size=(3, 3, 5)).astype(np.float32)
        y, _ = PEGrid(mode="float").conv2d_depthwise(x, w, stride=stride)
        ref = jax.lax.conv_general_dilated(
            jnp.asarray(x)[None], jnp.asarray(w)[:, :, None, :],
            (stride, stride), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"), feature_group_count=5)
        np.testing.assert_allclose(y, np.asarray(ref[0]), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# vectorized grid == per-scalar seed path, and ≥20× faster
# ---------------------------------------------------------------------------


def test_pe_grid_vectorized_matches_scalar():
    rng = np.random.default_rng(21)
    x = np.abs(rng.normal(size=(9, 8, 7))).astype(np.float32)
    w = rng.normal(size=(3, 3, 7, 2)).astype(np.float32)
    for stride in (1, 2):
        yv, sv = PEGrid(mode="log").conv2d(x, w, stride=stride)
        ys, ss = PEGrid(mode="log", vectorized=False).conv2d(x, w,
                                                             stride=stride)
        np.testing.assert_array_equal(yv, ys)
        assert sv == ss
    x1 = np.abs(rng.normal(size=(7, 6, 20))).astype(np.float32)
    w1 = rng.normal(size=(20, 3)).astype(np.float32)
    yv, sv = PEGrid(mode="log").conv2d_1x1(x1, w1)
    ys, ss = PEGrid(mode="log", vectorized=False).conv2d_1x1(x1, w1)
    np.testing.assert_array_equal(yv, ys)
    assert sv == ss


def test_pe_grid_vectorized_speedup():
    """Acceptance: ≥20× on a 16×16×6→4 layer vs the per-scalar path."""
    rng = np.random.default_rng(22)
    x = np.abs(rng.normal(size=(16, 16, 6))).astype(np.float32)
    w = rng.normal(size=(3, 3, 6, 4)).astype(np.float32)
    gv = PEGrid(mode="log")
    gs = PEGrid(mode="log", vectorized=False)
    gv._codes(x), gv._codes(w)  # warm the jax-jitted quantizer
    # best-of-3 on the fast (ms-scale) path so one scheduler stall on a
    # loaded CI machine can't fail the acceptance bound
    tv = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        yv, _ = gv.conv2d(x, w)
        tv = min(tv, time.perf_counter() - t0)
    t0 = time.perf_counter()
    ys, _ = gs.conv2d(x, w)
    ts = time.perf_counter() - t0
    np.testing.assert_array_equal(yv, ys)
    assert ts / tv >= 20, f"vectorized speedup only {ts/tv:.1f}x"
