"""Analytical dataflow model vs the paper's §6 aggregate claims."""

import pytest

from repro.core.accelerator import run_network
from repro.core.cost_model import (area_overhead_vs_linear,
                                   cost_adjusted_pe_count,
                                   peak_throughput_per_pe)
from repro.core.dataflow import (PEAK_GOPS_PAPER, LayerSpec, analyze_layer)


def test_vgg16_utilization_and_throughput():
    """Fig 19a/20: VGG16 ≈95 % util → ≈308 GOPS; Table 3: ≈240 ms."""
    perf = run_network("vgg16")
    assert 0.92 <= perf.mean_layer_utilization <= 0.97, perf.mean_layer_utilization
    assert abs(perf.throughput_gops_paper - 307.8) < 12.0
    assert abs(perf.latency_ms - 240.23) < 25.0  # aggregate model, ±10 %


def test_mobilenet_utilization():
    """Fig 19b/20: MobileNet v1 ≈83-84 % util."""
    perf = run_network("mobilenet_v1")
    assert 0.76 <= perf.mean_layer_utilization <= 0.92, perf.mean_layer_utilization


def test_resnet34_utilization():
    """Fig 19c/20: ResNet-34 ≈86-87 % util."""
    perf = run_network("resnet34")
    assert 0.80 <= perf.mean_layer_utilization <= 0.95, perf.mean_layer_utilization


def test_first_layer_3ch_is_50pct():
    """§6: VGG16 conv1_1 has 3 input channels → 3 of 6 matrices idle."""
    l = analyze_layer(LayerSpec("c", "conv", 224, 224, 3, 64, K=3, pad=1))
    assert abs(l.utilization - 0.5) < 0.02


def test_stride2_halves_utilization():
    s1 = analyze_layer(LayerSpec("a", "conv", 112, 112, 64, 64, K=3, stride=1, pad=1))
    s2 = analyze_layer(LayerSpec("b", "conv", 112, 112, 64, 64, K=3, stride=2, pad=1))
    assert s2.utilization < 0.62 * s1.utilization


def test_pwconv_high_util_when_divisible():
    l = analyze_layer(LayerSpec("p", "pwconv", 12, 6, 18, 4, K=1))
    assert l.utilization > 0.99


def test_psum_storage_fraction():
    l = analyze_layer(LayerSpec("c", "conv", 224, 224, 64, 64, K=3, pad=1))
    assert l.stored_psum_frac <= 3 / 18  # ≈11-17 % vs ~50 % in prior work


def test_ddr_traffic_log_vs_fp16():
    """7-bit codes cut off-chip traffic ≈2.3× vs fp16."""
    perf = run_network("vgg16")
    ratio = perf.ddr_bytes_fp16 / perf.ddr_bytes_log
    assert 2.0 < ratio < 2.5


def test_cost_model_anchors():
    assert cost_adjusted_pe_count() == 122  # Table 2 'PE number (adjusted)'
    assert abs(peak_throughput_per_pe() - 324 / 122) < 1e-9  # ≈2.66 ('2.7')
    assert peak_throughput_per_pe(adjusted=False) == 3.0  # +200 % peak/PE
    assert 0.04 < area_overhead_vs_linear() < 0.11  # '6 % area overhead'


def test_throughput_equals_util_times_peak():
    """Table 2 / Fig 20 accounting: GOPS = util × 324 exactly."""
    perf = run_network("resnet34")
    assert abs(perf.throughput_gops_paper -
               perf.mean_layer_utilization * PEAK_GOPS_PAPER) < 1e-9
