"""Elastic-scaling integration test: a checkpoint written on one world
size restores — correctly sharded — onto a different mesh, in a separate
process with 8 fake devices (the dry-run mechanism, scaled down).

This is the restart path after node loss: monitor → RestartPolicy
{"action": "restart", "new_world": …} → relaunch → restore with the new
mesh's shardings.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.checkpoint import save_checkpoint

_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json, sys
import jax, jax.numpy as jnp
import numpy as np
sys.path.insert(0, {src!r})
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_mesh
from repro.models import sharding
from repro.runtime.checkpoint import load_checkpoint

assert len(jax.devices()) == 8, jax.devices()
mesh = make_mesh((2, 4), ("data", "model"))

tpl = {{"w1": jax.ShapeDtypeStruct((16, 8), jnp.float32),
       "nested": {{"emb": jax.ShapeDtypeStruct((32, 16), jnp.bfloat16)}},
       "step": jax.ShapeDtypeStruct((), jnp.int32)}}
sh = {{"w1": NamedSharding(mesh, P("data", "model")),
      "nested": {{"emb": NamedSharding(mesh, P("model", "data"))}},
      "step": NamedSharding(mesh, P())}}
state, step = load_checkpoint({ckpt!r}, tpl, shardings=sh)

# verify: values exact and actually distributed across the 8 devices
w1 = state["w1"]
assert w1.sharding == sh["w1"], w1.sharding
assert len({{d for s in w1.addressable_shards for d in [s.device]}}) == 8
np.testing.assert_array_equal(
    np.asarray(w1), np.arange(16 * 8, dtype=np.float32).reshape(16, 8))
emb = state["nested"]["emb"]
assert emb.sharding == sh["nested"]["emb"]
np.testing.assert_array_equal(np.asarray(emb.astype(jnp.float32)),
                              np.ones((32, 16), np.float32) * 3.0)
assert step == 7 and int(state["step"]) == 7
print(json.dumps({{"ok": True, "devices": len(jax.devices())}}))
"""


def test_restore_onto_8_device_mesh(tmp_path):
    state = {"w1": jnp.arange(16 * 8, dtype=jnp.float32).reshape(16, 8),
             "nested": {"emb": jnp.full((32, 16), 3.0, jnp.bfloat16)},
             "step": jnp.asarray(7, jnp.int32)}
    save_checkpoint(str(tmp_path), 7, state)

    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = _CHILD.format(src=os.path.abspath(src), ckpt=str(tmp_path))
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    result = json.loads(out.stdout.strip().splitlines()[-1])
    assert result == {"ok": True, "devices": 8}
