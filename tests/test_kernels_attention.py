"""GQA-native flash_attention Pallas kernel + blockwise jnp vs full-softmax
oracle, and the redesigned `ops.attention` call surface (config=, legacy
kwarg deprecation, shape validation, traced decode offsets)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import AttentionConfig, attention, resolve_impl
from repro.kernels.ref import ref_attention


def _mk(b, tq, tk, h, hkv, d, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, tq, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, tk, hkv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, tk, hkv, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,h,hkv,d,window", [
    (1, 128, 4, 4, 64, None),     # MHA causal
    (2, 256, 8, 2, 64, None),     # GQA
    (1, 256, 4, 1, 64, 64),       # MQA + sliding window (gemma3 local)
    (1, 130, 4, 2, 64, None),     # ragged T
])
def test_pallas_attention_matches_ref(b, t, h, hkv, d, window, dtype):
    q, k, v = _mk(b, t, t, h, hkv, d, dtype)
    got = attention(q, k, v, causal=True, window=window, impl="pallas",
                    interpret=True)
    want = ref_attention(q, k, v, causal=True, window=window)
    rtol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=rtol)


# --------------------------------------------------------------------------
# GQA/MQA sweep: every impl agrees, static and dynamic (traced) offsets
# --------------------------------------------------------------------------


@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("hkv", [1, 2, 8])   # MQA, H/4 GQA, MHA (H = 8)
def test_gqa_pallas_blockwise_ref_agree(hkv, window):
    h, t, d = 8, 48, 16
    q, k, v = _mk(1, t, t, h, hkv, d, jnp.float32, seed=7)
    cfg = AttentionConfig(block_q=16, block_k=16)
    want = ref_attention(q, k, v, causal=True, window=window)
    got_p = attention(q, k, v, causal=True, window=window, impl="pallas",
                      config=cfg, interpret=True)
    got_b = attention(q, k, v, causal=True, window=window, impl="blockwise",
                      config=cfg)
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got_b), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("hkv", [1, 2, 8])
def test_gqa_dynamic_decode_offset_on_pallas(hkv):
    """Traced q_offset (decode at a dynamic cache index) runs on the Pallas
    impl — no blockwise fallback — and matches the full-prefill row."""
    h, t, d = 8, 64, 16
    q, k, v = _mk(1, t, t, h, hkv, d, jnp.float32, seed=8)
    full = ref_attention(q, k, v, causal=True)

    @jax.jit
    def decode(q1, k, v, off):
        return attention(q1, k, v, causal=True, q_offset=off, impl="pallas",
                         interpret=True,
                         config=AttentionConfig(block_q=8, block_k=16))

    got = decode(q[:, -1:], k, v, jnp.asarray(t - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)


def test_ring_k_offset_pallas_matches_blockwise():
    """Ring-buffer decode: traced k_offset masks never-written slots
    (absolute position < 0) identically on pallas and blockwise."""
    h, hkv, s, d = 4, 2, 32, 16
    q, k, v = _mk(1, 1, s, h, hkv, d, jnp.float32, seed=9)

    @jax.jit
    def ring(q, k, v, q_off, k_off):
        kw = dict(causal=True, window=8, q_offset=q_off, k_offset=k_off)
        a = attention(q, k, v, impl="pallas", interpret=True,
                      config=AttentionConfig(block_q=8, block_k=8), **kw)
        b = attention(q, k, v, impl="blockwise",
                      config=AttentionConfig(block_k=8), **kw)
        return a, b

    # k[0] sits at absolute position -9: the first 9 slots are unwritten
    a, b = ring(q, k, v, jnp.asarray(22, jnp.int32),
                jnp.asarray(-9, jnp.int32))
    want = ref_attention(q, k, v, causal=True, window=8, q_offset=22,
                         k_offset=-9)
    np.testing.assert_allclose(np.asarray(a), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(b), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------
# call-surface redesign: validation, deprecation, impl resolution
# --------------------------------------------------------------------------


def test_head_mismatch_raises_clear_valueerror():
    q, k, v = _mk(1, 8, 8, 6, 4, 16, jnp.float32)
    with pytest.raises(ValueError, match="H=6 query heads vs Hkv=4"):
        attention(q, k, v)
    with pytest.raises(ValueError, match="inconsistent attention operands"):
        attention(q, k[:, :4], v)


def test_legacy_kwargs_deprecated_but_equivalent():
    q, k, v = _mk(1, 32, 32, 4, 2, 16, jnp.float32, seed=3)
    with pytest.warns(DeprecationWarning, match="AttentionConfig"):
        old = attention(q, k, v, impl="blockwise", block_k=8,
                        gqa_broadcast=True)
    new = attention(q, k, v, impl="blockwise",
                    config=AttentionConfig(block_k=8, gqa_broadcast=True))
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))
    with pytest.raises(ValueError, match="not both"):
        attention(q, k, v, impl="blockwise", block_k=8,
                  config=AttentionConfig(block_k=8))


def test_resolve_impl_precedence():
    # off-TPU (CI): auto → blockwise, interpret default → True
    assert resolve_impl("attention") == ("blockwise", True)
    assert resolve_impl("attention", "pallas") == ("pallas", True)
    assert resolve_impl("attention", "pallas", False) == ("pallas", False)
    assert resolve_impl("conv2d", "pallas_im2col")[0] == "pallas_im2col"
    for op in ("log_matmul", "conv2d", "attention", "wkv6"):
        with pytest.raises(ValueError, match="unknown"):
            resolve_impl(op, "nope")
    # ops without an im2col variant reject conv-only aliases
    with pytest.raises(ValueError):
        resolve_impl("attention", "pallas_im2col")


# --------------------------------------------------------------------------
# legacy blockwise coverage (unchanged semantics)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("window", [None, 32])
def test_blockwise_attention_matches_ref(window):
    q, k, v = _mk(2, 96, 96, 4, 2, 32, jnp.float32, seed=2)
    got = attention(q, k, v, causal=True, window=window, impl="blockwise",
                    config=AttentionConfig(block_k=32))
    want = ref_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_decode_q_offset():
    """Single-token decode: q at position Tk-1 must equal full-prefill row."""
    b, t, h, d = 1, 64, 4, 32
    q, k, v = _mk(b, t, t, h, h, d, jnp.float32, seed=3)
    full = ref_attention(q, k, v, causal=True)
    last = attention(q[:, -1:], k, v, causal=True, q_offset=t - 1,
                     impl="blockwise", config=AttentionConfig(block_k=16))
    np.testing.assert_allclose(np.asarray(last[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)
    last_p = attention(q[:, -1:], k, v, causal=True, q_offset=t - 1,
                       impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(last_p[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)


def test_window_equals_full_when_large():
    q, k, v = _mk(1, 64, 64, 2, 2, 32, jnp.float32, seed=4)
    a = attention(q, k, v, causal=True, window=4096, impl="blockwise")
    b_ = attention(q, k, v, causal=True, window=None, impl="blockwise")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-5)
