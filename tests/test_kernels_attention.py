"""flash_attention Pallas kernel + blockwise jnp vs full-softmax oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import attention
from repro.kernels.ref import ref_attention


def _mk(b, tq, tk, h, hkv, d, dtype, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, tq, h, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, tk, hkv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, tk, hkv, d)), dtype)
    return q, k, v


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,h,hkv,d,window", [
    (1, 128, 4, 4, 64, None),     # MHA causal
    (2, 256, 8, 2, 64, None),     # GQA
    (1, 256, 4, 1, 64, 64),       # MQA + sliding window (gemma3 local)
    (1, 130, 4, 2, 64, None),     # ragged T
])
def test_pallas_attention_matches_ref(b, t, h, hkv, d, window, dtype):
    q, k, v = _mk(b, t, t, h, hkv, d, dtype)
    got = attention(q, k, v, causal=True, window=window, impl="pallas",
                    interpret=True)
    want = ref_attention(q, k, v, causal=True, window=window)
    rtol = 3e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=rtol)


@pytest.mark.parametrize("window", [None, 32])
def test_blockwise_attention_matches_ref(window):
    q, k, v = _mk(2, 96, 96, 4, 2, 32, jnp.float32, seed=2)
    got = attention(q, k, v, causal=True, window=window, impl="blockwise",
                    block_k=32)
    want = ref_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_decode_q_offset():
    """Single-token decode: q at position Tk-1 must equal full-prefill row."""
    b, t, h, d = 1, 64, 4, 32
    q, k, v = _mk(b, t, t, h, h, d, jnp.float32, seed=3)
    full = ref_attention(q, k, v, causal=True)
    last = attention(q[:, -1:], k, v, causal=True, q_offset=t - 1,
                     impl="blockwise", block_k=16)
    np.testing.assert_allclose(np.asarray(last[:, 0]), np.asarray(full[:, -1]),
                               rtol=2e-4, atol=2e-4)
    last_p = attention(q[:, -1:], k, v, causal=True, q_offset=t - 1,
                       impl="pallas", interpret=True)
    np.testing.assert_allclose(np.asarray(last_p[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-4, atol=2e-4)


def test_window_equals_full_when_large():
    q, k, v = _mk(1, 64, 64, 2, 2, 32, jnp.float32, seed=4)
    a = attention(q, k, v, causal=True, window=4096, impl="blockwise")
    b_ = attention(q, k, v, causal=True, window=None, impl="blockwise")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-5)
