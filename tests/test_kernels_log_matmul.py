"""log_matmul Pallas kernel (interpret=True) vs pure-jnp oracle, shape/dtype sweep."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.logquant import LogQuantConfig, log_quantize, quantize_tensor
from repro.kernels.log_matmul import log_matmul_pallas
from repro.kernels.ops import log_matmul
from repro.kernels.ref import ref_log_matmul

CFG = LogQuantConfig(per_channel=True)


def _mk(m, k, n, dtype, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(m, k)).astype(np.float32)
    w = (rng.normal(size=(k, n)) * 0.1).astype(np.float32)
    packed, scale = log_quantize(jnp.asarray(w), CFG)
    return jnp.asarray(x, dtype), packed, scale


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n", [
    (128, 128, 128),     # exactly one block
    (256, 384, 128),     # multi-block k
    (64, 128, 256),      # m smaller than block
    (130, 257, 129),     # ragged — exercises padding
    (8, 512, 64),        # skinny decode-like
])
def test_log_matmul_matches_oracle(m, k, n, dtype):
    x, packed, scale = _mk(m, k, n, dtype)
    got = log_matmul_pallas(x, packed, scale, CFG, interpret=True)
    want = ref_log_matmul(x, packed, scale, CFG)
    rtol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=rtol)


def test_log_matmul_blocksize_invariance():
    x, packed, scale = _mk(256, 256, 256, jnp.float32, seed=1)
    a = log_matmul_pallas(x, packed, scale, CFG, interpret=True,
                          block_m=128, block_k=128, block_n=128)
    b = log_matmul_pallas(x, packed, scale, CFG, interpret=True,
                          block_m=64, block_k=256, block_n=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_ops_wrapper_nd_batch():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 3, 96)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(96, 32)) * 0.2, jnp.float32)
    qt = quantize_tensor(w, CFG)
    got = log_matmul(x, qt, impl="pallas", interpret=True)
    want = ref_log_matmul(x.reshape(-1, 96), qt.packed, qt.scale, CFG)
    np.testing.assert_allclose(np.asarray(got).reshape(-1, 32),
                               np.asarray(want), rtol=1e-4, atol=1e-5)


def test_quantized_weights_within_sqrt2_halfstep():
    """End-to-end error budget: base-√2 rounding is ≤18.9 % per weight
    (median ≈9 %); with random sign cancellation the *output* relative error
    sits at the same ~9 % noise floor — the level the paper shows costs
    VGG16 only ≈3.5 top-1 points (vs ≈10 for base-2)."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(64, 512)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(512, 64)) * 0.05, jnp.float32)
    qt = quantize_tensor(w, CFG)
    deq = np.asarray(qt.dequantize(jnp.float32))
    wrel = np.abs(deq - np.asarray(w)) / np.abs(np.asarray(w))
    assert np.median(wrel) < 0.12 and wrel.max() <= 2 ** 0.25 - 1 + 1e-3
    exact = np.asarray(x @ w)
    got = np.asarray(log_matmul(x, qt, impl="pallas", interpret=True))
    rel = np.abs(got - exact) / (np.abs(exact) + 1e-3)
    assert np.median(rel) < 0.15  # the √2-grid noise floor
