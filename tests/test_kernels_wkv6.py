"""WKV6 chunked Pallas kernel + jnp chunked form vs sequential oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.ops import wkv6
from repro.kernels.ref import ref_wkv6


def _mk(b, t, h, kdim, vdim, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.normal(size=(b, t, h, kdim)) * 0.5, dtype)
    k = jnp.asarray(rng.normal(size=(b, t, h, kdim)) * 0.5, dtype)
    v = jnp.asarray(rng.normal(size=(b, t, h, vdim)) * 0.5, dtype)
    # data-dependent log decay in [-2, -0.02] (Finch: w = exp(-exp(x)))
    logw = jnp.asarray(-np.exp(rng.normal(size=(b, t, h, kdim)) * 0.5 - 1.5),
                       dtype)
    u = jnp.asarray(rng.normal(size=(h, kdim)) * 0.3, dtype)
    return r, k, v, logw, u


@pytest.mark.parametrize("b,t,h,kd,vd,chunk", [
    (1, 64, 2, 32, 32, 16),
    (2, 96, 2, 16, 32, 32),    # ragged T vs chunk
    (1, 33, 1, 8, 8, 16),      # T not multiple of chunk
])
def test_wkv6_pallas_matches_sequential(b, t, h, kd, vd, chunk):
    r, k, v, logw, u = _mk(b, t, h, kd, vd)
    o_ref, s_ref = ref_wkv6(r, k, v, logw, u)
    o, s = wkv6(r, k, v, logw, u, impl="pallas", chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-3, atol=1e-3)


def test_wkv6_jnp_chunked_matches_sequential():
    r, k, v, logw, u = _mk(2, 80, 3, 16, 16, seed=5)
    o_ref, s_ref = ref_wkv6(r, k, v, logw, u)
    o, s = wkv6(r, k, v, logw, u, impl="blockwise", chunk=32)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               rtol=1e-3, atol=1e-3)


def test_wkv6_state_carry_composes():
    """Running two halves with carried state == running the whole sequence."""
    r, k, v, logw, u = _mk(1, 64, 2, 16, 16, seed=7)
    o_full, s_full = ref_wkv6(r, k, v, logw, u)
    o1, s1 = wkv6(r[:, :32], k[:, :32], v[:, :32], logw[:, :32], u,
                  impl="blockwise", chunk=16)
    o2, s2 = wkv6(r[:, 32:], k[:, 32:], v[:, 32:], logw[:, 32:], u,
                  state=s1, impl="blockwise", chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(o_full), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.integers(1, 48), st.integers(1, 2))
def test_property_wkv6_chunk_invariance(b, t, h):
    """Chunk size must not change the result (associativity of the scan)."""
    r, k, v, logw, u = _mk(b, t, h, 8, 8, seed=t)
    o1, s1 = wkv6(r, k, v, logw, u, impl="blockwise", chunk=8)
    o2, s2 = wkv6(r, k, v, logw, u, impl="blockwise", chunk=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-3, atol=2e-3)
