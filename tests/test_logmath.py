"""Bit-exactness of the LUT+shift thread (eq. 8) vs the closed form (eq. 5)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.logmath import LogPEThread, log_product_fixed, make_frac_lut


def test_lut_contents_n1():
    # n=1 → 2 entries: 2^0 and 2^0.5 in fixed point (paper: "store 2 values")
    lut = make_frac_lut(frac_bits=1, out_frac_bits=12)
    assert lut[0] == 1 << 12
    assert lut[1] == round(2 ** 0.5 * (1 << 12))


@settings(max_examples=300, deadline=None)
@given(st.integers(-32, 31), st.integers(-32, 31),
       st.sampled_from([-1, 1]))
def test_shift_lut_matches_closed_form(wc, ac, sign):
    """|LUT(FRAC)>>¬INT  −  2^(g/2)| within fixed-point rounding bounds."""
    th = LogPEThread(frac_bits=1, out_frac_bits=20)
    v = th(wc, ac, sign)
    exact = th.closed_form(wc, ac, sign)
    # one LUT rounding (≤ 0.5 ulp at 2^20) scaled by 2^INT, plus shift floor
    g = wc + ac
    int_part = g >> 1
    tol = (0.5 * 2.0 ** max(int_part, 0) + 1.0) / (1 << 20) + \
          (2.0 ** int_part) * 1e-6
    assert abs(th.to_float(v) - exact) <= tol + abs(exact) * 1e-4


@settings(max_examples=100, deadline=None)
@given(st.integers(-16, 15), st.integers(-16, 15))
def test_nonnegative_shift_is_exact(wc, ac):
    """When INT(g) ≥ 0 the only error is the single LUT rounding."""
    th = LogPEThread(frac_bits=1, out_frac_bits=12)
    g = wc + ac
    if g < 0:
        return
    v = th(wc, ac, 1)
    exact = th.closed_form(wc, ac, 1)
    assert abs(th.to_float(v) - exact) <= 0.5 * 2.0 ** (g >> 1) / (1 << 12)


def test_zero_operand_gates_to_zero():
    th = LogPEThread()
    assert th(5, 3, 1, a_nonzero=False) == 0
    assert th(5, 3, 1, w_nonzero=False) == 0


@settings(max_examples=100, deadline=None)
@given(st.integers(-20, 20), st.integers(-20, 20), st.integers(-20, 20))
def test_code_add_is_log_product(wc, ac, bc):
    """(w·a)·b and w·(a·b) agree in the log domain: code adds commute."""
    assert log_product_fixed(wc + ac, bc, 1, 1, 16) == \
        log_product_fixed(wc, ac + bc, 1, 1, 16)


def test_base2_mode():
    """n=0 → base-2: LUT has a single entry, product is a pure shift."""
    th = LogPEThread(frac_bits=0, out_frac_bits=8)
    assert th(3, 2, 1) == (1 << 8) << 5
    assert th(-3, 1, -1) == -((1 << 8) >> 2)
