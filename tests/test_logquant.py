"""Unit + property tests for base-√2 log quantization (paper §3)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.logquant import (LogQuantConfig, fake_log_quant,
                                 linear_quantize, log_dequantize,
                                 log_quantize, quantization_snr_db,
                                 quantize_tensor, unpack)

CFG = LogQuantConfig(per_channel=False)


def test_roundtrip_exact_powers():
    # values exactly on the √2 grid must round-trip exactly
    codes = np.arange(CFG.code_min, 1)
    x = 2.0 ** (codes / CFG.steps)
    packed, scale = log_quantize(jnp.asarray(x, jnp.float32), CFG)
    deq = log_dequantize(packed, scale, CFG)
    np.testing.assert_allclose(np.asarray(deq), x, rtol=1e-5)  # fp32 exp2


def test_sign_and_zero():
    x = jnp.asarray([-1.0, 0.0, 1.0, -0.25, 0.5], jnp.float32)
    packed, scale = log_quantize(x, CFG)
    deq = np.asarray(log_dequantize(packed, scale, CFG))
    assert deq[1] == 0.0
    assert deq[0] == -deq[2]
    assert np.all(np.sign(deq) == np.sign(np.asarray(x)))


def test_relative_error_bound():
    # base-√2 rounding → magnitude error ≤ 2^(1/4) - 1 ≈ 18.9 % relative
    rng = np.random.default_rng(0)
    x = rng.normal(size=4096).astype(np.float32)
    packed, scale = log_quantize(jnp.asarray(x), CFG)
    deq = np.asarray(log_dequantize(packed, scale, CFG))
    nz = np.abs(x) > float(scale) * 2.0 ** (CFG.code_min / CFG.steps)
    rel = np.abs(deq[nz] - x[nz]) / np.abs(x[nz])
    assert rel.max() <= 2 ** 0.25 - 1 + 1e-3


def test_base_sqrt2_beats_base2_snr():
    """The paper's Fig-1 claim in SNR form: base √2 ≫ base 2."""
    rng = np.random.default_rng(1)
    w = rng.normal(size=(512, 512)).astype(np.float32) * 0.05
    xq2 = log_dequantize(*log_quantize(jnp.asarray(w),
                                       LogQuantConfig(frac_bits=0, per_channel=False)),
                         LogQuantConfig(frac_bits=0, per_channel=False))
    cfg_s2 = LogQuantConfig(frac_bits=1, per_channel=False)
    p, s = log_quantize(jnp.asarray(w), cfg_s2)
    xs2 = log_dequantize(p, s, cfg_s2)
    snr2 = quantization_snr_db(w, np.asarray(xq2))
    snr_s2 = quantization_snr_db(w, np.asarray(xs2))
    assert snr_s2 > snr2 + 4.0  # ~6 dB better in practice


def test_per_channel_scales():
    rng = np.random.default_rng(2)
    w = rng.normal(size=(64, 8)).astype(np.float32)
    w[:, 3] *= 100.0  # one hot channel
    q = quantize_tensor(jnp.asarray(w), LogQuantConfig(per_channel=True))
    deq = np.asarray(q.dequantize(jnp.float32))
    rel = np.abs(deq - w) / np.maximum(np.abs(w), 1e-6)
    assert np.median(rel) < 0.1  # hot channel does not wreck the others


def test_fake_quant_straight_through_grad():
    x = jnp.asarray(np.random.default_rng(3).normal(size=32), jnp.float32)
    g = jax.grad(lambda v: jnp.sum(fake_log_quant(v, CFG) ** 2))(x)
    # STE: grad = 2 * fq(x) exactly
    np.testing.assert_allclose(np.asarray(g),
                               2 * np.asarray(fake_log_quant(x, CFG)),
                               rtol=1e-5)


def test_linear_quantizer_clip():
    x = jnp.asarray([-100.0, 0.3, 100.0])
    q = np.asarray(linear_quantize(x, int_bits=3, frac_bits=2))
    assert q[0] == -4.0 and q[2] == 4.0 - 0.25
    assert abs(q[1] - 0.25) < 1e-6


def test_packed_layout_matches_paper_sign_msb():
    """Paper: w'[6] (the MSB above the 6-bit code) is the sign."""
    x = jnp.asarray([0.5, -0.5], jnp.float32)
    packed, _ = log_quantize(x, CFG)
    p = np.asarray(packed).astype(np.int32)
    assert (p[0] >> CFG.bits) & 1 == 0
    assert (p[1] >> CFG.bits) & 1 == 1
    assert (p[0] & ((1 << CFG.bits) - 1)) == (p[1] & ((1 << CFG.bits) - 1))


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(min_value=-1e3, max_value=1e3,
                          allow_nan=False, allow_infinity=False),
                min_size=1, max_size=64))
def test_property_dequant_monotone_in_magnitude(vals):
    """Quantization preserves sign and ordering of magnitudes (up to ties)."""
    x = np.asarray(vals, np.float32)
    packed, scale = log_quantize(jnp.asarray(x), CFG)
    deq = np.asarray(log_dequantize(packed, scale, CFG))
    # Sign preserved wherever the value is representable; magnitudes far
    # below the code range may underflow to an exact 0 (paper's zero code).
    nz = deq != 0
    assert np.all(np.sign(deq[nz]) == np.sign(x[nz]))
    if np.any(~nz):  # underflow only ever hits the smallest magnitudes
        assert np.abs(x)[~nz].max() <= np.abs(x)[nz].min() if np.any(nz) else True
    order = np.argsort(np.abs(x), kind="stable")
    dq_sorted = np.abs(deq)[order]
    assert np.all(np.diff(dq_sorted) >= -1e-7)  # non-decreasing


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=0, max_value=2))
def test_property_unpack_inverts_pack(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=128).astype(np.float32)
    packed, scale = log_quantize(jnp.asarray(x), CFG)
    code, sign, nz = unpack(packed, CFG)
    deq = np.asarray(sign * jnp.where(nz, jnp.exp2(code / CFG.steps), 0) * scale)
    np.testing.assert_allclose(
        deq, np.asarray(log_dequantize(packed, scale, CFG)), rtol=1e-6)
