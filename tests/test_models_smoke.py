"""Per-architecture smoke tests: reduced config, one forward + train-grad +
prefill/decode step on CPU; asserts shapes and no NaNs.  (Full configs are
exercised only via the dry-run.)"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import transformer as tf

jax.config.update("jax_platform_name", "cpu")


def _batch(cfg, B=2, T=16, seed=0):
    rng = np.random.default_rng(seed)
    b = {}
    if cfg.embed_inputs:
        b["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, size=(B, T)), jnp.int32)
    else:
        b["embeds"] = jnp.asarray(
            rng.normal(size=(B, T, cfg.d_model)) * 0.1, jnp.float32)
    if cfg.mrope_sections is not None:
        pos = np.broadcast_to(np.arange(T), (3, B, T)).copy()
        b["positions"] = jnp.asarray(pos, jnp.int32)
    b["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, T)),
                              jnp.int32)
    b["mask"] = jnp.ones((B, T), jnp.float32)
    return b


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_and_grad(arch):
    cfg = get_config(arch).reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)

    loss, metrics = jax.jit(
        lambda p, b: tf.lm_loss(p, b, cfg, xent_chunk=8))(params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert float(metrics["xent"]) > 0

    grads = jax.jit(jax.grad(
        lambda p, b: tf.lm_loss(p, b, cfg, xent_chunk=8)[0]))(params, batch)
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat), arch
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in flat), arch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_then_decode_matches_full_forward(arch):
    """Teacher-forced decode after prefill must match the one-shot forward."""
    cfg = get_config(arch).reduced()
    params = tf.init_params(cfg, jax.random.PRNGKey(1))
    B, T = 2, 12
    batch = _batch(cfg, B=B, T=T, seed=2)
    inputs = batch["tokens"] if cfg.embed_inputs else batch["embeds"]
    pos = batch.get("positions")

    h_full, _, _ = jax.jit(lambda p, x: tf.forward(p, x, cfg, positions=pos))(
        params, inputs)
    logits_full = tf.logits_fn(params, h_full, cfg)

    # prefill on the first Tp tokens, then decode the rest one by one
    Tp = 8
    cache = tf.init_cache(cfg, B, max_len=T, dtype=jnp.float32)
    pre_in = inputs[:, :Tp]
    pre_pos = None if pos is None else pos[:, :, :Tp]
    _, cache = tf.prefill(params, pre_in, cfg, cache, positions=pre_pos)

    outs = []
    for t in range(Tp, T):
        step_in = inputs[:, t:t + 1]
        step_pos = None if pos is None else pos[:, :, t:t + 1]
        logits, cache = tf.decode_step(params, step_in, cfg, cache,
                                       positions=step_pos)
        outs.append(logits[:, 0])
    got = np.stack([np.asarray(o, np.float32) for o in outs], axis=1)
    want = np.asarray(logits_full[:, Tp:], np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_param_counts_match_reported_scale():
    """Sanity: analytic parameter counts land near the advertised sizes."""
    expect = {"gemma-2b": (2.0e9, 3.5e9), "llama3-405b": (3.7e9 * 100, 4.4e11),
              "gemma3-1b": (0.9e9, 1.6e9), "qwen1.5-4b": (3.0e9, 4.5e9),
              "rwkv6-1.6b": (1.3e9, 2.2e9), "recurrentgemma-2b": (2.2e9, 3.4e9),
              "granite-moe-3b-a800m": (2.5e9, 4.0e9),
              "granite-moe-1b-a400m": (1.0e9, 1.7e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params():
    cfg = get_config("granite-moe-3b-a800m")
    assert cfg.active_param_count() < 0.55 * cfg.param_count()


def test_segments_cover_all_layers():
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        total = sum(len(u) * r for u, r in cfg.segments)
        assert total == cfg.n_layers, (arch, cfg.segments)
