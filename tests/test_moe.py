"""MoE dispatch unit tests: routing exactness, capacity drops, aux loss."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.moe import moe_ffn, moe_init


def _cfg(**over):
    cfg = get_config("granite-moe-1b-a400m").reduced()
    return dataclasses.replace(cfg, **over) if over else cfg


def _dense_ref(p, x, cfg):
    """Reference: route every token to its top-k experts, no capacity."""
    B, T, D = x.shape
    xt = np.asarray(x, np.float32).reshape(-1, D)
    logits = xt @ np.asarray(p["router"], np.float32)
    e = np.exp(logits - logits.max(-1, keepdims=True))
    probs = e / e.sum(-1, keepdims=True)
    K = cfg.top_k
    idx = np.argsort(-probs, axis=-1)[:, :K]
    out = np.zeros_like(xt)
    w1 = np.asarray(p["moe_w1"], np.float32)
    w3 = np.asarray(p["moe_w3"], np.float32)
    w2 = np.asarray(p["moe_w2"], np.float32)
    for n in range(xt.shape[0]):
        gv = probs[n, idx[n]]
        gv = gv / gv.sum()
        for j, ex in enumerate(idx[n]):
            h = (xt[n] @ w1[ex])
            h = h / (1 + np.exp(-h)) * (xt[n] @ w3[ex])  # silu gate
            out[n] += gv[j] * (h @ w2[ex])
    return out.reshape(B, T, D)


def test_moe_matches_dense_reference_when_capacity_large():
    cfg = _cfg()
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 6, cfg.d_model)),
                    jnp.float32)
    y, aux = moe_ffn(p, x, cfg, capacity=12)
    ref = _dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-3, atol=2e-3)
    assert float(aux) > 0


def test_capacity_drops_tokens():
    cfg = _cfg()
    p = moe_init(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(4, 8, cfg.d_model)),
                    jnp.float32)
    y_full, _ = moe_ffn(p, x, cfg, capacity=32)
    y_tight, _ = moe_ffn(p, x, cfg, capacity=1)
    # tight capacity must change (drop) some outputs, and dropped tokens
    # contribute zero rather than garbage
    assert not np.allclose(np.asarray(y_full), np.asarray(y_tight))
    assert np.all(np.isfinite(np.asarray(y_tight)))


def test_aux_loss_prefers_balance():
    cfg = _cfg()
    p = moe_init(jax.random.PRNGKey(2), cfg)
    E = cfg.n_experts
    # force the router to send everything to expert 0 → aux should exceed
    # the balanced router's aux
    p_skew = dict(p)
    skew = np.zeros(p["router"].shape, np.float32)
    skew[:, 0] = 5.0
    p_skew["router"] = p["router"] + jnp.asarray(skew)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(2, 16, cfg.d_model)),
                    jnp.float32)
    _, aux_bal = moe_ffn(p, x, cfg)
    _, aux_skew = moe_ffn(p_skew, x, cfg)
    assert float(aux_skew) > float(aux_bal)


def test_decode_capacity_is_lossless():
    cfg = _cfg()
    p = moe_init(jax.random.PRNGKey(4), cfg)
    x = jnp.asarray(np.random.default_rng(4).normal(size=(8, 1, cfg.d_model)),
                    jnp.float32)
    y, _ = moe_ffn(p, x, cfg)        # T==1 → capacity = N
    ref = _dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-3, atol=2e-3)


def test_grouped_dispatch_matches_global():
    """Grouped routing (G < N) must equal one-global-group routing when no
    tokens are dropped (capacity ≥ per-group demand) — the §Perf grouped
    dispatch is a layout change, not a semantics change."""
    import repro.models.moe as moe_mod
    from repro.configs.registry import get_config

    cfg = get_config("granite-moe-1b-a400m").reduced(
        n_layers=2, n_experts=4, top_k=2, capacity_factor=8.0)
    key = jax.random.PRNGKey(0)
    p = moe_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))

    old = moe_mod.DEFAULT_GROUP
    try:
        moe_mod.DEFAULT_GROUP = 8          # N=16 → 2 groups
        y_grouped, aux_g = moe_ffn(p, x, cfg)
        moe_mod.DEFAULT_GROUP = 16         # one global group
        y_global, aux_1 = moe_ffn(p, x, cfg)
    finally:
        moe_mod.DEFAULT_GROUP = old
    np.testing.assert_allclose(np.asarray(y_grouped, np.float32),
                               np.asarray(y_global, np.float32),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(float(aux_g), float(aux_1), rtol=1e-5)


def test_grouped_dispatch_every_kept_token_one_slot():
    """Property: within a group, each expert slot holds ≤ 1 token and each
    kept (token, k) choice occupies exactly 1 slot."""
    import repro.models.moe as moe_mod
    from repro.configs.registry import get_config

    cfg = get_config("granite-moe-1b-a400m").reduced(
        n_layers=2, n_experts=4, top_k=2, capacity_factor=1.0)
    p = moe_init(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, cfg.d_model))
    # instrument: reproduce the dispatch computed inside moe_ffn
    B, T, D = x.shape
    N, E, K = B * T, cfg.n_experts, cfg.top_k
    xt = x.reshape(N, D)
    logits = xt.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    _, gate_idx = jax.lax.top_k(probs, K)
    G = 8
    C = max(1, int(cfg.capacity_factor * G * K / E))
    onehot = jax.nn.one_hot(gate_idx, E).reshape(N // G, G, K, E)
    flat = onehot.reshape(N // G, G * K, E)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(N // G, G, K, E)
    pos = jnp.sum(pos * onehot, axis=-1)
    keep = pos < C
    slot_oh = jax.nn.one_hot(pos.astype(jnp.int32), C)
    dispatch = jnp.einsum("gnke,gnkc->gnec", onehot,
                          slot_oh * keep[..., None])
    # each (expert, slot) pair holds at most one token
    per_slot = np.asarray(jnp.sum(dispatch, axis=1))   # [n_g, E, C]
    assert per_slot.max() <= 1.0 + 1e-6
    # each kept (token, k) fills exactly one slot
    filled = np.asarray(jnp.sum(dispatch, axis=(2, 3)))  # [n_g, G]
    kept = np.asarray(jnp.sum(keep, axis=2))             # [n_g, G]
    np.testing.assert_allclose(filled, kept, atol=1e-6)
