"""Dedicated fleet-health coverage: restart-budget window arithmetic,
min-hosts boundary, elastic scale-up, straggler exclusion on restart."""

from repro.runtime.monitor import (HeartbeatMonitor, RestartPolicy,
                                   StragglerReport)


def _report(missing=(), stragglers=None, step=0):
    return StragglerReport(step=step, median_s=1.0, threshold_s=2.0,
                           stragglers=dict(stragglers or {}),
                           missing=list(missing))


def test_budget_window_expiry_is_sliding_not_reset():
    """Old restarts fall out of the window individually — one expiring
    frees exactly one budget slot, not the whole budget."""
    clk = [0.0]
    pol = RestartPolicy(budget=2, budget_window_s=100.0,
                        clock=lambda: clk[0])
    assert pol.decide(_report(["h1"]), 16)["action"] == "restart"   # t=0
    clk[0] = 50.0
    assert pol.decide(_report(["h2"]), 16)["action"] == "restart"   # t=50
    clk[0] = 90.0
    assert pol.decide(_report(["h3"]), 16)["action"] == "abort"
    clk[0] = 101.0        # t=0 restart expired; t=50 one still counted
    assert pol.decide(_report(["h4"]), 16)["action"] == "restart"
    clk[0] = 102.0        # window holds t=50 and t=101 → budget full again
    assert pol.decide(_report(["h5"]), 16)["action"] == "abort"


def test_min_hosts_fraction_exact_boundary():
    """healthy == fraction·total is still viable (abort only strictly
    below); one more loss tips it over."""
    pol = RestartPolicy(min_hosts_fraction=0.5, budget=10)
    at_boundary = _report([f"h{i}" for i in range(8)])      # 8/16 left
    assert pol.decide(at_boundary, 16)["action"] == "restart"
    below = _report([f"h{i}" for i in range(9)])            # 7/16 left
    assert pol.decide(below, 16)["action"] == "abort"


def test_restart_merges_stragglers_into_exclude():
    """A restart must shed the stragglers seen in the same report, or the
    reshard lands right back on the slow hosts."""
    pol = RestartPolicy()
    out = pol.decide(_report(missing=["h1"], stragglers={"h2": 9.0}), 16)
    assert out["action"] == "restart"
    assert out["exclude"] == ["h1", "h2"]
    assert out["new_world"] == 15        # stragglers excluded, not "lost"


def test_restart_exclude_deduplicates_overlap():
    pol = RestartPolicy()
    out = pol.decide(_report(missing=["h3"], stragglers={"h3": 9.0}), 16)
    assert out["exclude"] == ["h3"]


def test_elastic_scale_up_host_joins_report():
    mon = HeartbeatMonitor(["a", "b"], miss_timeout_s=10.0)
    for step in range(3):
        mon.record("a", step, 1.0)
        mon.record("b", step, 1.0)
    mon.record("c", 2, 1.0)              # scale-up: never in the ctor list
    assert "c" in mon.hosts
    rep = mon.report(step=2)
    assert not rep.missing               # c is tracked, not "missing"
    mon.record("c", 3, 9.0)              # and participates in detection
    assert "c" in mon.report(step=3).stragglers
