"""Observability stack: span tracer, metrics registry, kernel-dispatch
profiler, and the end-to-end serving/training telemetry acceptance paths."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, ShardedLoader
from repro.kernels import autotune, ops
from repro.models import transformer
from repro.obs import kernel_profile as kprof
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.runtime.monitor import HeartbeatMonitor
from repro.serving.engine import EngineConfig, Request, ServeEngine
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import TrainConfig, train


@pytest.fixture(autouse=True)
def _clean_obs(monkeypatch):
    """Each test starts with env gates unset, empty buffers, no overrides."""
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    monkeypatch.delenv("REPRO_KERNEL_PROFILE", raising=False)
    obs_trace.set_enabled(None)
    kprof.set_enabled(None)
    obs_trace.clear()
    kprof.clear()
    yield
    obs_trace.set_enabled(None)
    kprof.set_enabled(None)
    obs_trace.clear()
    kprof.clear()


def _small_model():
    cfg = get_config("gemma-2b").reduced(n_layers=2, vocab=64, d_model=16,
                                         d_ff=32, head_dim=8, n_heads=2)
    return cfg, transformer.init_params(cfg, jax.random.PRNGKey(0))


# ------------------------------------------------------------------- tracer


def test_tracer_disabled_is_shared_noop():
    assert not obs_trace.enabled()
    s1, s2 = obs_trace.span("a"), obs_trace.span("b", x=1)
    assert s1 is s2                       # one shared null span, no allocs
    with s1:
        pass
    obs_trace.instant("marker")
    obs_trace.add_complete("ext", 0, 100)
    assert obs_trace.events() == []


def test_tracer_env_gate_and_override(monkeypatch):
    monkeypatch.setenv("REPRO_TRACE", "1")
    assert obs_trace.enabled()
    obs_trace.set_enabled(False)          # override beats env
    assert not obs_trace.enabled()
    obs_trace.set_enabled(None)           # defer back to env
    assert obs_trace.enabled()
    monkeypatch.setenv("REPRO_TRACE", "off")
    assert not obs_trace.enabled()


def test_tracer_ring_buffer_bounded():
    t = obs_trace.Tracer(capacity=4)
    t.set_enabled(True)
    for i in range(10):
        with t.span(f"s{i}"):
            pass
    evs = t.events()
    assert len(evs) == 4
    assert [e[1] for e in evs] == ["s6", "s7", "s8", "s9"]  # keeps latest


def test_tracer_chrome_export_loadable(tmp_path):
    obs_trace.set_enabled(True)
    with obs_trace.span("work", uid=7) as sp:
        sp.set(tokens=3)
    obs_trace.instant("mark", note="x")
    path = tmp_path / "sub" / "trace.json"   # exercises makedirs
    obs_trace.export_chrome_trace(str(path))
    payload = json.loads(path.read_text())
    assert payload["displayTimeUnit"] == "ms"
    evs = payload["traceEvents"]
    by_name = {e["name"]: e for e in evs}
    work = by_name["work"]
    assert work["ph"] == "X" and work["dur"] >= 0
    assert work["args"] == {"uid": 7, "tokens": 3}
    assert by_name["mark"]["ph"] == "i" and by_name["mark"]["s"] == "t"
    for e in evs:
        assert {"ts", "pid", "tid", "cat"} <= set(e)


def test_traced_decorator():
    calls = []

    @obs_trace.traced("fancy", kind="unit")
    def fn(x):
        calls.append(x)
        return x * 2

    assert fn(3) == 6                     # disabled: plain passthrough
    assert obs_trace.events() == []
    obs_trace.set_enabled(True)
    assert fn(4) == 8
    (ev,) = obs_trace.events()
    assert ev[1] == "fancy" and ev[5] == {"kind": "unit"}
    assert calls == [3, 4]


# ------------------------------------------------------------------ metrics


def test_log_bucket_bounds():
    b = obs_metrics.log_bucket_bounds(1e-3, 1.0, per_decade=3)
    assert b[0] == pytest.approx(1e-3)
    assert b[-1] >= 1.0
    assert all(x < y for x, y in zip(b, b[1:]))
    # constant ratio (geometric spacing)
    ratios = [y / x for x, y in zip(b, b[1:])]
    assert max(ratios) == pytest.approx(min(ratios))
    with pytest.raises(ValueError):
        obs_metrics.log_bucket_bounds(1.0, 0.5)


def test_counter_gauge():
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("reqs", route="a")
    c.inc()
    c.inc(3)
    assert c.value == 4
    assert reg.counter("reqs", route="a") is c       # get-or-create
    assert reg.counter("reqs", route="b") is not c   # distinct labels
    g = reg.gauge("depth")
    g.set(5)
    g.inc(-2)
    assert g.value == 3


def test_histogram_percentiles_and_snapshot():
    reg = obs_metrics.MetricsRegistry()
    h = reg.histogram("lat_s")
    for v in (0.001, 0.002, 0.002, 0.003, 0.5):
        h.record(v)
    assert h.count == 5
    assert h.sum == pytest.approx(0.508)
    # bucket-resolution estimates stay clamped to observed min/max and
    # ordered across percentiles
    p50, p99 = h.percentile(50), h.percentile(99)
    assert 0.001 <= p50 <= 0.5
    assert p50 <= p99 <= 0.5
    snap = h.snapshot()
    assert snap["count"] == 5 and snap["min"] == 0.001 and snap["max"] == 0.5
    assert snap["mean"] == pytest.approx(0.508 / 5)
    assert snap["buckets"][-1][0] == "+Inf"
    assert sum(c for _, c in snap["buckets"]) == 5
    assert snap["p50"] == pytest.approx(p50)
    # empty histogram is well-defined
    assert reg.histogram("empty").percentile(50) == 0.0


def test_registry_kind_collision():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_registry_snapshot_and_prometheus():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("hits", op="conv").inc(2)
    reg.gauge("depth").set(1.5)
    h = reg.histogram("lat", bounds=(0.1, 1.0))
    h.record(0.05)
    h.record(0.5)
    h.record(7.0)

    snap = reg.snapshot()
    assert snap["counters"] == {'hits{op="conv"}': 2}
    assert snap["gauges"] == {"depth": 1.5}
    assert snap["histograms"]["lat"]["count"] == 3

    text = reg.to_prometheus()
    assert "# TYPE hits counter" in text
    assert 'hits{op="conv"} 2' in text
    assert "# TYPE lat histogram" in text
    # cumulative buckets: ≤0.1 → 1, ≤1.0 → 2, +Inf → 3
    assert 'lat_bucket{le="0.1"} 1' in text
    assert 'lat_bucket{le="1.0"} 2' in text
    assert 'lat_bucket{le="+Inf"} 3' in text
    assert "lat_sum 7.55" in text and "lat_count 3" in text


def test_registry_dump_json(tmp_path):
    reg = obs_metrics.MetricsRegistry()
    reg.counter("n").inc()
    path = tmp_path / "m.json"
    reg.dump_json(str(path))
    assert json.loads(path.read_text())["counters"]["n"] == 1


# ----------------------------------------------------------- kernel profiler


def test_profiler_disabled_passthrough():
    p = kprof.KernelProfiler()
    assert p.dispatch("op", "ref", "k", {}, lambda: 42, traced=False) == 42
    assert p.time_program("prog", lambda: jnp.ones(2)).shape == (2,)
    snap = p.snapshot()
    assert snap["records"] == [] and snap["programs"] == {}


def test_profiler_eager_first_vs_steady():
    p = kprof.KernelProfiler()
    p.set_enabled(True)
    fn = lambda: jnp.ones(4)
    for _ in range(3):
        p.dispatch("attention", "ref", "k1", {"total": 64}, fn, traced=False)
    (rec,) = p.snapshot()["records"]
    assert rec["calls"] == 3 and rec["traced_calls"] == 0
    assert rec["first_us"] is not None
    assert rec["steady_us"] is not None and rec["steady_source"] == "self"
    assert rec["steady_us_min"] <= rec["steady_us"]
    assert rec["bytes"]["total"] == 64


def test_profiler_traced_dispatch_inherits_program_time():
    kprof.set_enabled(True)
    q = jnp.ones((1, 8, 2, 4))
    kv = jnp.ones((1, 8, 2, 4))
    f = jax.jit(lambda q, k, v: ops.attention(q, k, v, impl="blockwise"))
    for _ in range(3):                    # 1 compile + 2 steady
        kprof.time_program("myprog", lambda: f(q, kv, kv))
    snap = kprof.snapshot()
    recs = [r for r in snap["records"] if r["op"] == "attention"]
    assert recs, "jit-traced attention dispatch must be recorded"
    rec = recs[0]
    assert rec["traced_calls"] >= 1       # staged once, cached afterwards
    assert rec["program"] == "myprog"
    assert rec["steady_source"] == "program:myprog"
    assert rec["steady_us"] is not None and rec["bytes"]["total"] > 0
    prog = snap["programs"]["myprog"]
    assert prog["calls"] == 3 and prog["first_us"] is not None
    assert prog["steady_us"] is not None


def test_profiler_eager_ops_dispatch_records():
    kprof.set_enabled(True)
    q = jnp.ones((1, 8, 2, 4))
    kv = jnp.ones((1, 8, 2, 4))
    for _ in range(2):
        ops.attention(q, kv, kv, impl="blockwise")
    recs = [r for r in kprof.snapshot()["records"]
            if r["op"] == "attention" and r["calls"] == 2]
    assert recs
    rec = recs[0]
    assert rec["impl"] == "blockwise"
    assert rec["key"].startswith("attention|")
    assert rec["bytes"]["total"] > 0
    assert rec["steady_source"] == "self"
    # dispatch also feeds the process-wide latency histogram
    h = obs_metrics.REGISTRY.histogram(
        "kernel_dispatch_us", bounds=obs_metrics.US_BUCKETS,
        op="attention", impl="blockwise", phase="steady")
    assert h.count >= 1


def test_autotune_lookup_hit_miss_counters(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_PATH", str(tmp_path / "tune.json"))
    monkeypatch.setattr(autotune, "PACKAGED_DIR", str(tmp_path / "pkg"))
    autotune.reset_cache()
    try:
        hit = obs_metrics.REGISTRY.counter("autotune_lookup",
                                           op="attention", result="hit_user")
        miss = obs_metrics.REGISTRY.counter("autotune_lookup",
                                            op="attention", result="miss")
        h0, m0 = hit.value, miss.value
        key = autotune.attention_key(1, 8, 8, 2, 2, 4, backend="interpret")
        assert autotune.lookup(key) is None
        assert (hit.value, miss.value) == (h0, m0 + 1)
        autotune.record(key, {"block_q": 8, "block_k": 8}, 1.0)
        assert autotune.lookup(key) == {"block_q": 8, "block_k": 8}
        assert (hit.value, miss.value) == (h0 + 1, m0 + 1)
    finally:
        autotune.reset_cache()            # drop the tmp table from cache


# ------------------------------------------------------- training telemetry


def test_train_step_histogram_feeds_monitor():
    cfg, params = _small_model()
    loss_fn = lambda p, b: transformer.lm_loss(p, b, cfg, xent_chunk=8)
    tcfg = TrainConfig(opt=OptimizerConfig(lr=1e-2, warmup_steps=0,
                                           schedule="constant",
                                           total_steps=10), log_every=2)
    ld = ShardedLoader(DataConfig(seq_len=8, global_batch=2, vocab=64,
                                  seed=0))
    reg = obs_metrics.MetricsRegistry()
    mon = HeartbeatMonitor(["host0"])
    train(loss_fn, params, ld, tcfg, num_steps=4,
          metrics=reg, monitor=mon, host="host0")
    hist = reg.snapshot()["histograms"]["train_step_s"]
    assert hist["count"] == 4 and hist["min"] > 0
    # monitor heartbeats come from the same per-step event stream
    rep = mon.report(step=3)
    assert not rep.missing
    assert mon._last_seen["host0"][1] == 3     # last recorded step
    # same event also lands in the tracer when it is on (train() donates
    # its state buffers, so the second run needs fresh params)
    obs_trace.set_enabled(True)
    params2 = transformer.init_params(cfg, jax.random.PRNGKey(0))
    train(loss_fn, params2, ld, tcfg, num_steps=2, metrics=reg, monitor=mon)
    steps = [e for e in obs_trace.events() if e[1] == "train_step"]
    assert len(steps) == 2


# -------------------------------------------- serving acceptance (ISSUE 8)


def test_engine_trace_acceptance(tmp_path, monkeypatch):
    """REPRO_TRACE=1 + a run over 8 mixed-length requests must yield a
    loadable Chrome trace with prefill/decode spans and a metrics snapshot
    with TTFT/tokens-per-s histograms plus per-op kernel records carrying
    impl, analytic bytes moved, and a steady-µs attribution."""
    monkeypatch.setenv("REPRO_TRACE", "1")
    cfg, params = _small_model()
    eng = ServeEngine(cfg, params, EngineConfig(max_batch=4, max_prompt=16,
                                                max_len=64))
    rng = np.random.default_rng(0)
    for uid in range(8):
        T = int(rng.integers(2, 13))
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(1, cfg.vocab, size=T)
                           .astype(np.int32),
                           max_new_tokens=3 + uid % 4))
    done = eng.run()
    assert len(done) == 8

    # ---- Chrome trace: loadable, with the serving lifecycle spans
    path = tmp_path / "trace.json"
    obs_trace.export_chrome_trace(str(path))
    payload = json.loads(path.read_text())
    names = {e["name"] for e in payload["traceEvents"]}
    assert {"enqueue", "prefill", "decode", "retire"} <= names
    for e in payload["traceEvents"]:
        if e["ph"] == "X":
            assert e["dur"] >= 0

    # ---- request timelines are causally ordered
    for r in done:
        tl = r.timeline
        assert tl["enqueue"] <= tl["prefill_start"] <= tl["first_token"] \
            <= tl["retire"]

    # ---- engine metrics: one TTFT and one tokens/s sample per request
    snap = eng.metrics_snapshot()
    hists = snap["engine"]["histograms"]
    assert hists["serve_ttft_s"]["count"] == 8
    assert hists["serve_tokens_per_s"]["count"] == 8
    assert hists["serve_prefill_s"]["count"] == 8
    assert snap["engine"]["counters"]["serve_requests_retired"] == 8
    assert snap["stats"]["prefill_calls"] == 8

    # ---- kernel records: every dispatched op carries impl/bytes/steady
    recs = snap["kernels"]["records"]
    assert recs, "engine run must record kernel dispatches"
    for r in recs:
        assert r["impl"]
        assert r["bytes"]["total"] > 0
        assert r["steady_us"] is not None, r
        assert r["steady_source"].startswith(("self", "program:")), r
    progs = snap["kernels"]["programs"]
    assert {"prefill", "decode"} <= set(progs)
    assert progs["decode"]["steady_us"] is not None


def test_engine_telemetry_off_records_nothing():
    obs_trace.set_enabled(True)           # tracer on, engine forced off
    cfg, params = _small_model()
    eng = ServeEngine(cfg, params, EngineConfig(max_batch=2, max_prompt=16,
                                                max_len=32, telemetry="off"))
    eng.submit(Request(uid=0, prompt=np.array([1, 2, 3], np.int32),
                       max_new_tokens=3))
    done = eng.run()
    assert done[0].timeline == {}
    snap = eng.metrics_snapshot()
    assert snap["engine"]["histograms"]["serve_ttft_s"]["count"] == 0
    assert {e[1] for e in obs_trace.events()}.isdisjoint(
        {"enqueue", "prefill", "retire"})
    assert eng.stats["prefill_calls"] == 1    # compat counters always on


def test_engine_rejects_bad_telemetry_mode():
    cfg, params = _small_model()
    with pytest.raises(ValueError, match="telemetry"):
        ServeEngine(cfg, params, EngineConfig(telemetry="sometimes"))
