"""PE-grid functional model vs dense convolution oracle + paper examples."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.dataflow import LayerSpec, analyze_layer
from repro.core.pe_grid import PEGrid, TOTAL_THREADS


def _conv_oracle(x, w, stride=1):
    """x: [H,W,C], w: [K,K,C,P], valid padding."""
    out = jax.lax.conv_general_dilated(
        jnp.asarray(x)[None], jnp.asarray(w),
        window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return np.asarray(out[0])


@pytest.mark.parametrize("H,W,C,P,stride", [
    (12, 6, 1, 1, 1),    # the paper's Fig-5 example
    (12, 6, 1, 1, 2),
    (6, 8, 3, 2, 1),
    (18, 10, 7, 3, 1),   # channel remainder (7 = 6+1)
    (12, 7, 2, 2, 2),
])
def test_conv3x3_float_mode_exact(H, W, C, P, stride):
    rng = np.random.default_rng(42)
    x = rng.normal(size=(H, W, C)).astype(np.float32)
    w = rng.normal(size=(3, 3, C, P)).astype(np.float32)
    y, stats = PEGrid(mode="float").conv2d(x, w, stride=stride)
    np.testing.assert_allclose(y, _conv_oracle(x, w, stride), rtol=1e-4,
                               atol=1e-4)
    assert stats.cycles > 0


def test_paper_fig5_counts():
    """§5.1: 12×6 input, 3×3 s1 → 8 cycles, 360 MACs, 83.3 % matrix util,
    3 stored psums per (band, j) boundary → 2/18..3/18 storage."""
    x = np.random.default_rng(0).normal(size=(12, 6, 1)).astype(np.float32)
    w = np.random.default_rng(1).normal(size=(3, 3, 1, 1)).astype(np.float32)
    y, stats = PEGrid(mode="float").conv2d(x, w)
    assert y.shape == (10, 4, 1)
    assert stats.cycles == 8
    assert stats.useful_macs == 360
    assert abs(stats.active_utilization - 45 / 54) < 1e-9  # 83.3 %
    # 4 boundary (band, j) pairs × 3 psums stored, of 8 × 18 produced
    assert stats.stored_psums == 12
    assert stats.psum_storage_fraction <= 3 / 18 + 1e-9


def test_paper_1x1_counts():
    """§5.2: 6×6×6 input, 6 1×1×6 filters → 12 cycles, 100 % util."""
    rng = np.random.default_rng(3)
    x = rng.normal(size=(6, 6, 6)).astype(np.float32)
    w = rng.normal(size=(6, 6)).astype(np.float32)
    y, stats = PEGrid(mode="float").conv2d_1x1(x, w)
    np.testing.assert_allclose(y, x.reshape(36, 6) @ w @ np.eye(6)
                               if False else (x.reshape(36, 6) @ w).reshape(6, 6, 6),
                               rtol=1e-4)
    assert stats.cycles == 12
    assert stats.useful_macs == 1296
    assert abs(stats.active_utilization - 1.0) < 1e-9


@pytest.mark.parametrize("H,W,C,P", [(6, 6, 4, 5), (12, 6, 20, 3)])
def test_conv1x1_float_mode_exact(H, W, C, P):
    rng = np.random.default_rng(7)
    x = rng.normal(size=(H, W, C)).astype(np.float32)
    w = rng.normal(size=(C, P)).astype(np.float32)
    y, _ = PEGrid(mode="float").conv2d_1x1(x, w)
    np.testing.assert_allclose(y, (x.reshape(-1, C) @ w).reshape(H, W, P),
                               rtol=1e-4, atol=1e-5)


def test_log_mode_matches_dequantized_conv():
    """The grid in log mode ≈ conv of the log-dequantized tensors; the only
    extra error is the per-product fixed-point LUT rounding."""
    from repro.core.logquant import LogQuantConfig, log_quantize, log_dequantize
    rng = np.random.default_rng(11)
    x = np.abs(rng.normal(size=(6, 6, 2))).astype(np.float32)  # post-ReLU
    w = rng.normal(size=(3, 3, 2, 1)).astype(np.float32)
    cfg = LogQuantConfig(per_channel=False)
    grid = PEGrid(mode="log", quant_cfg=cfg, out_frac_bits=16)
    y, _ = grid.conv2d(x, w)
    xp, xs = log_quantize(jnp.asarray(x), cfg)
    wp, ws = log_quantize(jnp.asarray(w), cfg)
    xd = np.asarray(log_dequantize(xp, xs, cfg))
    wd = np.asarray(log_dequantize(wp, ws, cfg))
    ref = _conv_oracle(xd, wd)
    np.testing.assert_allclose(y, ref, rtol=5e-3, atol=5e-3)


def test_dataflow_analytical_vs_grid_cycles():
    """The analytical model is the steady-state (streamed-band) count: never
    more cycles than the band-quantized functional grid, and close to it.
    (The paper itself uses the band-quantized count in the §5.1 example but
    fractional streaming in Table 3 — see EXPERIMENTS.md.)"""
    for (H, W, C, P, s) in [(12, 6, 1, 1, 1), (12, 8, 6, 2, 1), (18, 6, 3, 2, 1)]:
        x = np.zeros((H, W, C), np.float32)
        w = np.zeros((3, 3, C, P), np.float32)
        _, stats = PEGrid(mode="float").conv2d(x, w, stride=s)
        spec = LayerSpec("t", "conv", H, W, C, P, K=3, stride=s, pad=0)
        perf = analyze_layer(spec)
        assert perf.cycles <= stats.cycles
        assert perf.cycles >= 0.6 * stats.cycles
