"""§Perf feature tests: packed serving weights, GQA broadcast, sequence-
parallel flags, sharding sanitize fallback."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.kernels.ops import _blockwise_attention
from repro.models import transformer
from repro.models.sharding import sanitize_spec
from repro.serving.quantize import (QUANT_LEAVES, quantize_params,
                                    quantized_fraction)


def test_gqa_broadcast_matches_repeat():
    rng = np.random.default_rng(0)
    B, Tq, Tk, H, Hkv, D = 2, 8, 16, 8, 2, 16
    q = jnp.asarray(rng.normal(size=(B, Tq, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Tk, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Tk, Hkv, D)), jnp.float32)
    a = _blockwise_attention(q, k, v, causal=True, window=None, scale=None,
                             q_offset=Tk - Tq, block_k=8)
    b = _blockwise_attention(q, k, v, causal=True, window=None, scale=None,
                             q_offset=Tk - Tq, block_k=8,
                             gqa_broadcast=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


def test_bf16_acc_close_to_f32():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 8, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 32, 4, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 32, 4, 16)), jnp.float32)
    a = _blockwise_attention(q, k, v, causal=True, window=None, scale=None,
                             q_offset=24, block_k=16)
    b = _blockwise_attention(q, k, v, causal=True, window=None, scale=None,
                             q_offset=24, block_k=16,
                             acc_dtype=jnp.bfloat16)
    # bf16 math keeps ~2 decimal digits
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=5e-2, atol=5e-2)


def test_quantized_params_forward_close():
    """Serving with packed 6-bit weights ≈ serving with fake-quant weights
    (same codes; the pack/decode path must agree with the STE path)."""
    cfg = get_config("gemma-2b").reduced(n_layers=2)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray([[3, 1, 4, 1, 5]], jnp.int32)

    qparams = quantize_params(params)
    frac = quantized_fraction(qparams)
    assert frac > 0.05  # matmul kernels packed (embeds stay fp)

    h_fp, _, _ = transformer.forward(params, toks, cfg)
    h_q, _, _ = transformer.forward(qparams, toks, cfg)
    # fake-quant config runs STE-dequantized weights — the reference
    cfg_fq = dataclasses.replace(cfg, quant="logq6")
    h_fq, _, _ = transformer.forward(params, toks, cfg_fq)

    q_vs_fq = float(jnp.max(jnp.abs(h_q - h_fq)))
    q_vs_fp = float(jnp.max(jnp.abs(h_q - h_fp)))
    assert np.isfinite(q_vs_fq)
    # packed path tracks the fake-quant path far better than fp32
    # (same quantization grid; per-channel vs per-tensor scales differ)
    assert q_vs_fq < q_vs_fp


def test_quantized_params_stacked_scan_slices():
    """Stacked [n_rep, K, N] QuantizedTensors survive the layer scan."""
    cfg = get_config("qwen1.5-4b").reduced(n_layers=4)
    params = transformer.init_params(cfg, jax.random.PRNGKey(1))
    qparams = quantize_params(params)
    toks = jnp.asarray([[2, 7, 1, 8]], jnp.int32)
    h, _, _ = transformer.forward(qparams, toks, cfg)
    assert h.shape == (1, 4, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(h, np.float32)))


@pytest.mark.parametrize("variant_kw", [
    dict(attn_shard="heads"),
    dict(attn_shard="seq", residual_shard="seq"),
    dict(attn_shard="seq", residual_shard="seq", sp_style="megatron"),
    dict(gqa_broadcast=True, attn_acc_dtype=jnp.bfloat16),
])
def test_perf_variants_numerically_equal_baseline(variant_kw):
    """Sharding/layout flags must not change results (CPU, 1 device —
    constraints are no-ops numerically; exercises the code paths)."""
    cfg = get_config("gemma3-1b").reduced(n_layers=2)
    params = transformer.init_params(cfg, jax.random.PRNGKey(2))
    toks = jnp.asarray([[5, 3, 9, 2, 6, 1]], jnp.int32)
    h0, _, _ = transformer.forward(params, toks, cfg)
    cfg_v = dataclasses.replace(cfg, **variant_kw)
    h1, _, _ = transformer.forward(params, toks, cfg_v)
    np.testing.assert_allclose(np.asarray(h0, np.float32),
                               np.asarray(h1, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_sanitize_spec_drops_nondivisible():
    from jax.sharding import PartitionSpec as P

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    m = FakeMesh()
    # divisible stays
    assert sanitize_spec(m, P("model", None), (32, 7)) == P("model", None)
    # non-divisible dims drop to None (granite vocab 49155, batch 1)
    assert sanitize_spec(m, P("model", "data"), (49155, 32)) \
        == P(None, "data")
    assert sanitize_spec(m, P(("data", "model"), None), (1, 8)) \
        == P(None, None)
    # shorter spec than rank is padded
    assert sanitize_spec(m, P("data"), (16, 8, 4)) == P("data", None, None)
