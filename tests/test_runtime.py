"""Checkpoint (save/restore/reshard/rotation/resume) + monitor tests."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, ShardedLoader
from repro.models.transformer import init_params, lm_loss
from repro.runtime.checkpoint import (CheckpointManager, latest_step,
                                      load_checkpoint, save_checkpoint)
from repro.runtime.monitor import HeartbeatMonitor, RestartPolicy
from repro.training.optimizer import OptimizerConfig
from repro.training.train_loop import TrainConfig, init_train_state, train


def _state():
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4),
                       "nested": {"b": jnp.ones((5,), jnp.bfloat16)}},
            "step": jnp.asarray(7, jnp.int32)}


def test_save_load_roundtrip(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 7, state)
    restored, step = load_checkpoint(str(tmp_path), state)
    assert step == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), state, restored)
    assert restored["params"]["nested"]["b"].dtype == jnp.bfloat16


def test_load_into_abstract_template(tmp_path):
    state = _state()
    save_checkpoint(str(tmp_path), 3, state)
    tpl = jax.eval_shape(lambda: state)
    restored, _ = load_checkpoint(str(tmp_path), tpl)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))


def test_restore_reshards_onto_new_mesh(tmp_path):
    """Save unsharded, restore sharded onto a 2-device mesh (elastic)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 1, state)
    # CPU test: 1 device — a trivial mesh still exercises the device_put path
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored, _ = load_checkpoint(str(tmp_path), state, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))


def test_manager_rotation_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        mgr.save(s, {"x": jnp.asarray(s)}, sync=True)
    assert latest_step(str(tmp_path)) == 30
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2
    restored, step = mgr.restore({"x": jnp.asarray(0)})
    assert step == 30 and int(restored["x"]) == 30


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(5, _state())          # async
    mgr.wait()
    assert mgr.latest_step() == 5


def test_atomicity_no_tmp_left(tmp_path):
    save_checkpoint(str(tmp_path), 1, _state())
    assert not [d for d in os.listdir(tmp_path) if d.endswith(".tmp")]


def test_train_resume_bitexact(tmp_path):
    """Fault-tolerance end-to-end: train 6 steps straight vs train 3,
    checkpoint, 'crash', restore, train 3 — identical final params."""
    cfg = get_config("gemma-2b").reduced(n_layers=2, vocab=64, d_model=16,
                                         d_ff=32, head_dim=8, n_heads=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    loss_fn = lambda p, b: lm_loss(p, b, cfg, xent_chunk=8)
    tcfg = TrainConfig(opt=OptimizerConfig(lr=1e-2, warmup_steps=0,
                                           schedule="constant",
                                           total_steps=10), log_every=1)
    ld = ShardedLoader(DataConfig(seq_len=8, global_batch=2, vocab=64,
                                  seed=1))

    # train() donates state buffers — give each run its own params copy
    fresh = lambda: init_params(cfg, jax.random.PRNGKey(0))
    sA, _ = train(loss_fn, fresh(), ld, tcfg, num_steps=6)

    sB, _ = train(loss_fn, fresh(), ld, tcfg, num_steps=3)
    save_checkpoint(str(tmp_path), 3, sB)
    tpl = jax.eval_shape(lambda: sB)
    sB2, step = load_checkpoint(str(tmp_path), tpl)
    sB2, _ = train(loss_fn, params, ld, tcfg, num_steps=3, start_step=step,
                   state=sB2)

    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), sA["params"], sB2["params"])


# ------------------------------------------------------------------ monitor


def test_straggler_detection():
    mon = HeartbeatMonitor([f"h{i}" for i in range(8)], window=4)
    for step in range(4):
        for i in range(8):
            t = 1.0 if i != 5 else 3.5   # h5 is slow
            mon.record(f"h{i}", step, t)
    rep = mon.report(step=3)
    assert list(rep.stragglers) == ["h5"]
    assert not rep.missing


def test_missing_host_detection():
    clk = [0.0]
    mon = HeartbeatMonitor(["a", "b"], miss_timeout_s=10.0,
                           clock=lambda: clk[0])
    mon.record("a", 0, 1.0)
    mon.record("b", 0, 1.0)
    clk[0] = 12.0
    mon.record("a", 1, 1.0)
    clk[0] = 20.0                    # b silent for 20s, a for only 8s
    rep = mon.report(step=1)
    assert rep.missing == ["b"]


def test_restart_policy_restart_then_budget_abort():
    clk = [0.0]
    pol = RestartPolicy(budget=2, budget_window_s=100.0,
                        clock=lambda: clk[0])
    rep = lambda miss: type("R", (), {"missing": miss, "stragglers": {}})()
    assert pol.decide(rep(["h1"]), 16)["action"] == "restart"
    clk[0] = 1.0
    assert pol.decide(rep(["h2"]), 16)["action"] == "restart"
    clk[0] = 2.0
    assert pol.decide(rep(["h3"]), 16)["action"] == "abort"
    clk[0] = 200.0                   # budget window expired → allowed again
    assert pol.decide(rep(["h4"]), 16)["action"] == "restart"


def test_restart_policy_abort_below_min_hosts():
    pol = RestartPolicy(min_hosts_fraction=0.75)
    rep = type("R", (), {"missing": [f"h{i}" for i in range(8)],
                         "stragglers": {}})()
    assert pol.decide(rep, 16)["action"] == "abort"


def test_restart_policy_exclude_stragglers():
    pol = RestartPolicy()
    rep = type("R", (), {"missing": [], "stragglers": {"h7": 9.0}})()
    out = pol.decide(rep, 16)
    assert out == {"action": "exclude", "hosts": ["h7"]}
