"""Serving engine tests: correctness vs naive full-forward decode, ragged
continuous batching, recurrent-arch prefill hygiene."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import transformer
from repro.serving.engine import EngineConfig, Request, ServeEngine


def _make(arch, **red):
    cfg = get_config(arch).reduced(**red)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _naive_greedy(cfg, params, prompt, n_new):
    """Reference: rerun the full forward on the growing sequence."""
    toks = list(prompt)
    for _ in range(n_new):
        h, _, _ = transformer.forward(
            params, jnp.asarray([toks], jnp.int32), cfg)
        logits = transformer.logits_fn(params, h[:, -1:], cfg)
        toks.append(int(jnp.argmax(logits[0, 0])))
    return toks[len(prompt):]


@pytest.mark.parametrize("arch", ["gemma-2b", "qwen1.5-4b", "rwkv6-1.6b",
                                  "recurrentgemma-2b", "gemma3-1b"])
def test_engine_matches_naive_greedy(arch):
    cfg, params = _make(arch)
    prompt = np.array([5, 17, 42, 7, 99], np.int32)
    n_new = 6
    ref = _naive_greedy(cfg, params, prompt, n_new)

    eng = ServeEngine(cfg, params, EngineConfig(max_batch=2, max_prompt=16,
                                                max_len=32))
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=n_new))
    done = eng.run()
    assert len(done) == 1
    assert done[0].output == ref


def test_engine_pallas_attention_decode():
    """EngineConfig(attn_impl="pallas") serves decode on the Pallas kernel:
    per-slot positions are traced scalars riding the kernel's
    scalar-prefetch operand (vmapped across slots), so generations match
    the blockwise engine exactly."""
    cfg, params = _make("gemma-2b")
    prompt = np.array([5, 17, 42, 7, 99], np.int32)
    outs = {}
    for impl in (None, "pallas"):
        eng = ServeEngine(cfg, params,
                          EngineConfig(max_batch=2, max_prompt=16,
                                       max_len=32, attn_impl=impl))
        eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=4))
        outs[impl] = eng.run()[0].output
    assert eng.cfg.attn_impl == "pallas"
    assert outs[None] == outs["pallas"]


def test_engine_ragged_batch_isolation():
    """Two prompts of different lengths decode exactly as they would alone."""
    cfg, params = _make("gemma-2b")
    p1 = np.array([3, 1, 4, 1, 5, 9, 2, 6], np.int32)
    p2 = np.array([2, 7, 1], np.int32)
    r1 = _naive_greedy(cfg, params, p1, 5)
    r2 = _naive_greedy(cfg, params, p2, 5)

    eng = ServeEngine(cfg, params, EngineConfig(max_batch=2, max_prompt=16,
                                                max_len=32))
    eng.submit(Request(uid=1, prompt=p1, max_new_tokens=5))
    eng.submit(Request(uid=2, prompt=p2, max_new_tokens=5))
    done = {r.uid: r.output for r in eng.run()}
    assert done[1] == r1
    assert done[2] == r2


def test_engine_continuous_batching_refill():
    """More requests than slots: slots are refilled, all finish, outputs
    match the solo references (no cross-request cache pollution)."""
    cfg, params = _make("rwkv6-1.6b")  # recurrent: hardest hygiene case
    prompts = [np.arange(1, 4 + i, dtype=np.int32) for i in range(5)]
    refs = [_naive_greedy(cfg, params, p, 4) for p in prompts]

    eng = ServeEngine(cfg, params, EngineConfig(max_batch=2, max_prompt=16,
                                                max_len=32))
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    done = {r.uid: r.output for r in eng.run()}
    assert len(done) == 5
    for i, ref in enumerate(refs):
        assert done[i] == ref, f"request {i}"
    assert eng.stats["prefill_calls"] == 5


def test_engine_max_len_stops_generation():
    cfg, params = _make("gemma-2b")
    eng = ServeEngine(cfg, params, EngineConfig(max_batch=1, max_prompt=8,
                                                max_len=10))
    eng.submit(Request(uid=0, prompt=np.array([1, 2, 3], np.int32),
                       max_new_tokens=100))
    done = eng.run()
    assert done[0].done
    assert len(done[0].output) <= 10 - 3 + 1


def test_engine_rejects_nonpositive_max_new_tokens():
    cfg, params = _make("gemma-2b")
    eng = ServeEngine(cfg, params, EngineConfig(max_batch=1, max_prompt=8,
                                                max_len=16))
    with pytest.raises(ValueError, match="max_new_tokens must be >= 1"):
        eng.submit(Request(uid=0, prompt=np.array([1, 2], np.int32),
                           max_new_tokens=0))
    assert not eng.queue                 # rejected request never queued


def test_engine_queue_admits_fifo():
    cfg, params = _make("gemma-2b")
    eng = ServeEngine(cfg, params, EngineConfig(max_batch=1, max_prompt=8,
                                                max_len=32))
    for uid in range(3):
        eng.submit(Request(uid=uid, prompt=np.array([1 + uid, 2], np.int32),
                           max_new_tokens=2))
    done = eng.run()
    assert [r.uid for r in done] == [0, 1, 2]


def test_engine_temperature_sampling_deterministic_per_seed():
    cfg, params = _make("gemma-2b")

    def run_once():
        eng = ServeEngine(cfg, params, EngineConfig(max_batch=1,
                                                    max_prompt=8, max_len=32))
        eng.submit(Request(uid=0, prompt=np.array([1, 2], np.int32),
                           max_new_tokens=5, temperature=1.0, seed=42))
        return eng.run()[0].output

    assert run_once() == run_once()
