"""Data pipeline, optimizer, grad compression, and train-loop tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, ShardedLoader
from repro.models.transformer import init_params, lm_loss
from repro.training.grad_compress import (CompressorConfig, compressor_init,
                                          compress_decompress,
                                          log_compress_gradients)
from repro.training.optimizer import (OptimizerConfig, clip_by_global_norm,
                                      lr_at, make_optimizer)
from repro.training.train_loop import (TrainConfig, init_train_state,
                                       make_train_step, train)

# ---------------------------------------------------------------- data


def test_loader_deterministic_and_sharded():
    base = dict(seq_len=16, global_batch=8, vocab=100, seed=7)
    full = ShardedLoader(DataConfig(**base))
    b0 = full.batch(3)
    # exact resume: same (seed, step) → identical batch
    np.testing.assert_array_equal(b0["tokens"],
                                  ShardedLoader(DataConfig(**base))
                                  .batch(3)["tokens"])
    # host shards tile the global batch
    shards = [ShardedLoader(DataConfig(**base, n_hosts=4, host_id=h)).batch(3)
              for h in range(4)]
    np.testing.assert_array_equal(
        np.concatenate([s["tokens"] for s in shards]), b0["tokens"])
    assert b0["tokens"].max() < 100 and b0["tokens"].min() >= 0
    # different steps differ
    assert not np.array_equal(b0["tokens"], full.batch(4)["tokens"])


def test_loader_memmap_roundtrip(tmp_path):
    data = np.arange(17 * 10, dtype=np.int32) % 50
    p = tmp_path / "toks.bin"
    data.tofile(p)
    ld = ShardedLoader(DataConfig(seq_len=16, global_batch=2, vocab=50,
                                  source="memmap", path=str(p)))
    b = ld.batch(0)
    assert b["tokens"].shape == (2, 16)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


# ---------------------------------------------------------------- optimizer


def test_lr_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_ratio=0.1)
    lrs = [float(lr_at(cfg, s)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0           # warmup rises
    assert abs(lrs[9] - 1.0) < 1e-6
    assert lrs[-1] < 0.15                    # decays to ~min ratio
    assert all(b <= a + 1e-9 for a, b in zip(lrs[9:], lrs[10:]))  # monotone


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 10.0) < 1e-5
    cn = float(jnp.sqrt(sum(jnp.sum(x ** 2)
                            for x in jax.tree.leaves(clipped))))
    assert abs(cn - 1.0) < 1e-5


@pytest.mark.parametrize("name", ["adamw", "sgd"])
def test_optimizer_descends_quadratic(name):
    cfg = OptimizerConfig(name=name, lr=0.1, warmup_steps=0, total_steps=200,
                          schedule="constant", weight_decay=0.0)
    init, update = make_optimizer(cfg)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = init(params)
    for _ in range(150):
        grads = jax.tree.map(lambda p: 2 * p, params)  # d/dx x^2
        params, state = update(grads, state, params)
    assert float(jnp.abs(params["x"]).max()) < 0.3


# ---------------------------------------------------------------- compression


def test_compress_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=4096).astype(np.float32))
    q = compress_decompress(g)
    rel = np.abs(np.asarray(q) - np.asarray(g)) / np.maximum(np.abs(g), 1e-9)
    nz = np.abs(np.asarray(g)) > 1e-4 * np.abs(np.asarray(g)).max()
    assert np.median(rel[nz]) < 0.1


def test_error_feedback_preserves_mean_signal():
    """EF compression: accumulated quantization error does not bias the sum
    of applied gradients (the defining property of error feedback)."""
    rng = np.random.default_rng(1)
    true_g = rng.normal(size=2048).astype(np.float32) * 1e-2
    grads = {"w": jnp.asarray(true_g)}
    cfg = CompressorConfig()
    state = compressor_init(grads, cfg)
    applied = np.zeros_like(true_g)
    for _ in range(30):
        q, state = log_compress_gradients(grads, state, cfg)
        applied += np.asarray(q["w"])
    drift = np.abs(applied - 30 * true_g)
    # residual is bounded by one quantization step, not growing with steps
    assert drift.max() < np.abs(true_g).max() * 2.5


def test_small_tensors_bypass_compression():
    grads = {"scale": jnp.ones((8,)), "big": jnp.ones((4096,))}
    cfg = CompressorConfig(min_size=1024)
    state = compressor_init(grads, cfg)
    q, _ = log_compress_gradients(grads, state, cfg)
    np.testing.assert_array_equal(np.asarray(q["scale"]), np.ones((8,)))


# ---------------------------------------------------------------- train loop


def _tiny_setup(microbatches=1, grad_compress=False):
    cfg = get_config("gemma-2b").reduced(n_layers=2, vocab=128, d_model=32,
                                         d_ff=64, head_dim=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    loss_fn = lambda p, b: lm_loss(p, b, cfg, xent_chunk=16)
    tcfg = TrainConfig(
        opt=OptimizerConfig(lr=1e-2, warmup_steps=2, total_steps=50,
                            schedule="constant"),
        microbatches=microbatches, grad_compress=grad_compress, log_every=1)
    ld = ShardedLoader(DataConfig(seq_len=16, global_batch=4, vocab=128,
                                  seed=0))
    return cfg, params, loss_fn, tcfg, ld


def test_train_loop_loss_decreases():
    _, params, loss_fn, tcfg, ld = _tiny_setup()
    state, hist = train(loss_fn, params, ld, tcfg, num_steps=20)
    assert int(state["step"]) == 20
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert np.isfinite(hist[-1]["loss"])


def test_grad_accumulation_matches_full_batch():
    """microbatches=2 must produce the same update as one big batch."""
    _, params, loss_fn, tcfg, ld = _tiny_setup()
    batch = ld.batch(0)
    s1 = init_train_state(params, tcfg)
    s1, m1 = jax.jit(make_train_step(loss_fn, tcfg))(s1, batch)

    tcfg2 = TrainConfig(opt=tcfg.opt, microbatches=2, log_every=1)
    s2 = init_train_state(params, tcfg2)
    s2, m2 = jax.jit(make_train_step(loss_fn, tcfg2))(s2, batch)

    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                                rtol=2e-4, atol=2e-5),
        s1["params"], s2["params"])


def test_train_loop_with_compression_still_learns():
    _, params, loss_fn, tcfg, ld = _tiny_setup(grad_compress=True)
    state, hist = train(loss_fn, params, ld, tcfg, num_steps=20)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_hooks_fire():
    _, params, loss_fn, tcfg, ld = _tiny_setup()
    seen = []
    train(loss_fn, params, ld, tcfg, num_steps=5,
          hooks=[lambda step, st, m: seen.append(step)])
    assert seen == [0, 1, 2, 3, 4]
