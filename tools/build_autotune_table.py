#!/usr/bin/env python
"""Build (or CI-check) the packaged autotune warm-start tables.

The paper's premise (NeuroMAX §IV, like Shen et al.'s partitioning and
MPNA's per-layer dataflows) is that the per-layer schedule is a
*compile-time* artifact — first inference should never pay a tuning
sweep.  This tool walks the model zoo (`models/cnn.py` `CNN_ZOO` +
`configs/neuromax_cnn.py`) by shape tracing (`trace_conv_shapes`: init
and apply under `jax.eval_shape`, no parameters materialised), adds the
serving attention shapes, runs the candidate sweep per shape, and emits
one read-only table per backend under
``src/repro/kernels/autotune_tables/<backend>.json`` — the packaged tier
`kernels/autotune.lookup` consults after the writable user tier.

Two sweep modes:

  * default — the **analytic** sweep: every VMEM-fitting candidate
    (`candidate_configs` / `attention_candidate_configs`) is scored with
    the hardware-honest traffic model (`conv_traffic_bytes(lanes=128)` /
    `attention_traffic_bytes`), ties broken toward larger MXU tiles.
    Fully deterministic: regenerating the table yields a byte-identical
    file, so it can be checked in and diffed.
  * ``--measure`` — time candidates on the live backend via the real
    tuners (`autotune_conv2d` / `autotune_attention`).  Non-deterministic
    by nature; use it to regenerate a table on real hardware (the
    measured winners also land in your user-tier cache).

Usage:

    PYTHONPATH=src python tools/build_autotune_table.py          # rebuild
    PYTHONPATH=src python tools/build_autotune_table.py --check  # CI gate

``--check`` parses each packaged table, verifies the schema version
matches `SCHEMA_VERSION`, re-walks the zoo at the parameters recorded in
the table's ``meta`` block, and fails listing any uncovered key.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.analysis.roofline import HBM_BW  # noqa: E402
from repro.configs.neuromax_cnn import CONFIG  # noqa: E402
from repro.kernels import autotune  # noqa: E402
from repro.kernels.flash_attention import attention_traffic_bytes  # noqa: E402
from repro.kernels.log_conv2d import (conv_traffic_bytes,  # noqa: E402
                                      fused_conv_geometry)
from repro.models.cnn import zoo_conv_shapes  # noqa: E402

# serving decode/prefill attention launch shapes (mirrors the
# BENCH_attention.json case list): (B, Tq, Tk, H, Hkv, D, causal, window)
ATTENTION_SHAPES = [
    [1, 1, 4096, 8, 2, 64, True, None],     # decode, GQA rep=4
    [1, 1, 8192, 8, 2, 64, True, None],     # decode, GQA rep=4, 8k ctx
    [1, 1, 4096, 8, 1, 64, True, None],     # decode, MQA
    [1, 128, 4096, 8, 2, 64, True, None],   # prefill chunk, GQA rep=4
    [1, 1, 4096, 8, 8, 64, True, None],     # decode, MHA control
]

DEFAULT_BACKENDS = ("interpret", "cpu", "tpu")


def _walk_kwargs(args_or_meta) -> dict:
    g = (args_or_meta.get if isinstance(args_or_meta, dict)
         else lambda k, d=None: getattr(args_or_meta, k.replace("-", "_")))
    return dict(batch=g("batch", 1), img=g("img", 224),
                n_classes=g("n_classes", 1000), cin=g("cin", 3),
                width_mult=g("width_mult", 1.0))


def conv_keys_for(shapes: list[dict], backend: str) -> list[tuple[str, dict]]:
    out = []
    for s in shapes:
        key = autotune.conv_key(
            s["B"], s["H"], s["W"], s["C"], s["K"], s["Cout"],
            stride=s["stride"], padding=s["padding"], groups=s["groups"],
            cfg=CONFIG.qcfg, backend=backend)
        out.append((key, s))
    return out


def attention_keys_for(shapes, backend: str) -> list[tuple[str, list]]:
    return [(autotune.attention_key(B, Tq, Tk, H, Hkv, D, causal=causal,
                                    window=window, backend=backend),
             [B, Tq, Tk, H, Hkv, D, causal, window])
            for B, Tq, Tk, H, Hkv, D, causal, window in shapes]


# ---------------------------------------------------------------------------
# analytic sweep (deterministic)
# ---------------------------------------------------------------------------


def analytic_conv_winner(s: dict) -> tuple[dict, float]:
    """Best candidate by modeled 128-lane HBM traffic; ties go to larger
    channel tiles (fewer grid steps).  Returns (config, estimated_us)."""
    shape_kw = dict(stride=s["stride"], padding=s["padding"],
                    groups=s["groups"])
    args = (s["B"], s["H"], s["W"], s["C"], s["K"], s["Cout"])
    cands = (autotune.candidate_configs(*args, **shape_kw)
             or [autotune.default_config(*args, **shape_kw)])
    best, best_score, best_total = None, None, None
    for cfg in cands:
        t = conv_traffic_bytes("pallas", *args, **shape_kw, config=cfg,
                               lanes=128)
        g = fused_conv_geometry(*args, **shape_kw, **cfg)
        score = (t["act_w"], -(g["bcin"] * g["bcout"]))
        if best_score is None or score < best_score:
            best, best_score, best_total = cfg, score, t["total"]
    return best, best_total / HBM_BW * 1e6


def analytic_attention_winner(shape) -> tuple[dict, float]:
    B, Tq, Tk, H, Hkv, D = shape[:6]
    args = (B, Tq, Tk, H, Hkv, D)
    cands = (autotune.attention_candidate_configs(*args)
             or [autotune.default_attention_config(*args)])
    best, best_score, best_total = None, None, None
    for cfg in cands:
        t = attention_traffic_bytes("pallas", *args, **cfg)
        score = (t["total"], -(cfg["block_q"] * cfg["block_k"]))
        if best_score is None or score < best_score:
            best, best_score, best_total = cfg, score, t["total"]
    return best, best_total / HBM_BW * 1e6


# ---------------------------------------------------------------------------
# measured sweep (live backend; non-deterministic)
# ---------------------------------------------------------------------------


def measured_conv_winner(s: dict, backend: str, reps: int) -> tuple[dict,
                                                                    float]:
    from repro.core.logquant import quantize_tensor
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(s["B"], s["H"], s["W"], s["C"]))
                    .astype(np.float32))
    w = jnp.asarray(rng.normal(
        size=(s["K"], s["K"], s["C"] // s["groups"], s["Cout"]))
        .astype(np.float32))
    qt = quantize_tensor(w, CONFIG.qcfg)
    best = autotune.autotune_conv2d(
        x, qt.packed, qt.scale, qt.cfg, stride=s["stride"],
        padding=s["padding"], groups=s["groups"],
        interpret=(backend == "interpret"), reps=reps)
    key = autotune.conv_key(s["B"], s["H"], s["W"], s["C"], s["K"],
                            s["Cout"], stride=s["stride"],
                            padding=s["padding"], groups=s["groups"],
                            cfg=CONFIG.qcfg, backend=backend)
    return best, autotune._load()["entries"][key]["us"]


def measured_attention_winner(shape, backend: str, reps: int) -> tuple[dict,
                                                                       float]:
    B, Tq, Tk, H, Hkv, D, causal, window = shape
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, Tq, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Tk, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Tk, Hkv, D)), jnp.float32)
    best = autotune.autotune_attention(
        q, k, v, causal=causal, window=window,
        interpret=(backend == "interpret"), reps=reps)
    key = autotune.attention_key(B, Tq, Tk, H, Hkv, D, causal=causal,
                                 window=window, backend=backend)
    return best, autotune._load()["entries"][key]["us"]


# ---------------------------------------------------------------------------
# build / check
# ---------------------------------------------------------------------------


def build_table(backend: str, args) -> dict:
    walk = _walk_kwargs(args)
    shapes = zoo_conv_shapes(**walk)
    entries = {}
    for key, s in conv_keys_for(shapes, backend):
        if args.measure:
            cfg, us = measured_conv_winner(s, backend, args.reps)
            how = "measured"
        else:
            cfg, us = analytic_conv_winner(s)
            how = "analytic"
        entries[key] = {"config": cfg, "us": round(us, 2),
                        "when": "packaged", "how": how, "nets": s["nets"]}
    for key, shape in attention_keys_for(ATTENTION_SHAPES, backend):
        if args.measure:
            cfg, us = measured_attention_winner(shape, backend, args.reps)
            how = "measured"
        else:
            cfg, us = analytic_attention_winner(shape)
            how = "analytic"
        entries[key] = {"config": cfg, "us": round(us, 2),
                        "when": "packaged", "how": how}
    return {"version": autotune.SCHEMA_VERSION,
            "generated_by": "tools/build_autotune_table.py",
            "meta": dict(walk, qbits=CONFIG.qcfg.bits,
                         qfrac=CONFIG.qcfg.frac_bits,
                         attention_shapes=ATTENTION_SHAPES),
            "entries": entries}


def check_table(path: str, backend: str) -> list[str]:
    """→ list of problems (empty = table is valid and covers the zoo)."""
    try:
        with open(path) as f:
            table = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable ({e})"]
    problems = []
    if table.get("version") != autotune.SCHEMA_VERSION:
        problems.append(f"{path}: schema version {table.get('version')} != "
                        f"SCHEMA_VERSION {autotune.SCHEMA_VERSION}")
        return problems
    entries = table.get("entries", {})
    meta = table.get("meta", {})
    shapes = zoo_conv_shapes(**_walk_kwargs(meta))
    for key, _ in conv_keys_for(shapes, backend):
        if key not in entries:
            problems.append(f"{path}: missing conv entry {key}")
    att = meta.get("attention_shapes", ATTENTION_SHAPES)
    att = [tuple(a) for a in att]
    for key, _ in attention_keys_for(att, backend):
        if key not in entries:
            problems.append(f"{path}: missing attention entry {key}")
    for key, e in entries.items():
        if not isinstance(e.get("config"), dict):
            problems.append(f"{path}: entry {key} has no config dict")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="build/check the packaged autotune warm-start tables")
    ap.add_argument("--backends", nargs="*", default=list(DEFAULT_BACKENDS))
    ap.add_argument("--out", default=autotune.PACKAGED_DIR,
                    help="tables directory (default: the packaged tier)")
    ap.add_argument("--img", type=int, default=224)
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--n-classes", type=int, default=1000)
    ap.add_argument("--cin", type=int, default=3)
    ap.add_argument("--width-mult", type=float, default=1.0)
    ap.add_argument("--measure", action="store_true",
                    help="time candidates on the live backend instead of "
                         "the deterministic analytic sweep")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--check", action="store_true",
                    help="validate existing tables (schema + zoo coverage) "
                         "instead of building")
    args = ap.parse_args(argv)

    if args.check:
        problems = []
        for backend in args.backends:
            path = os.path.join(args.out, f"{backend}.json")
            probs = check_table(path, backend)
            problems += probs
            if not probs:
                n = len(json.load(open(path))["entries"])
                print(f"{path}: ok ({n} entries cover the zoo)")
        if problems:
            print("\n".join(problems[:40]), file=sys.stderr)
            print(f"FAIL: {len(problems)} problem(s)", file=sys.stderr)
            return 1
        return 0

    if args.measure:
        live = ("interpret" if jax.default_backend() != "tpu"
                else jax.default_backend())
        bad = [b for b in args.backends if b != live]
        if bad:
            print(f"--measure can only time the live backend ({live}); "
                  f"drop {bad} or run without --measure", file=sys.stderr)
            return 1

    os.makedirs(args.out, exist_ok=True)
    for backend in args.backends:
        table = build_table(backend, args)
        path = os.path.join(args.out, f"{backend}.json")
        with open(path, "w") as f:
            json.dump(table, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {path}: {len(table['entries'])} entries "
              f"({'measured' if args.measure else 'analytic'} sweep)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
