"""Check intra-repo markdown links: every relative link/image target in
the repo's .md files must exist, and every `#fragment` on an intra-repo
markdown link must match a heading or explicit anchor in the target.

    python tools/check_md_links.py [root]

Exits non-zero listing every broken reference.  External links
(http/https/mailto) and bare anchors into the same file's headings are
checked for the latter only.  No dependencies beyond the stdlib — this
runs in the CI docs job.
"""

from __future__ import annotations

import pathlib
import re
import sys

# [text](target) — excluding images handled identically; stop at the
# first unescaped ')'; ignore code spans by stripping fenced/inline code
LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
FENCE_RE = re.compile(r"```.*?```", re.S)
INLINE_CODE_RE = re.compile(r"`[^`]*`")
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def _anchors(md_path: pathlib.Path) -> set[str]:
    """GitHub-style slugs of every heading, plus explicit <a name=…>."""
    out = set()
    text = FENCE_RE.sub("", md_path.read_text(encoding="utf-8"))
    for line in text.splitlines():
        m = re.match(r"#{1,6}\s+(.*)", line)
        if m:
            slug = m.group(1).strip().lower()
            slug = re.sub(r"[`*]|\[|\]|\(.*?\)", "", slug)
            slug = re.sub(r"[^\w\- ]", "", slug)
            out.add(slug.replace(" ", "-"))
    for m in re.finditer(r"<a\s+(?:name|id)=[\"']([^\"']+)[\"']", text):
        out.add(m.group(1))
    return out


def check(root: pathlib.Path) -> list[str]:
    errors = []
    md_files = [p for p in root.rglob("*.md")
                if ".git" not in p.parts and "node_modules" not in p.parts]
    for md in md_files:
        text = FENCE_RE.sub("", md.read_text(encoding="utf-8"))
        text = INLINE_CODE_RE.sub("", text)
        for m in LINK_RE.finditer(text):
            target = m.group(1)
            if target.startswith(EXTERNAL):
                continue
            target, _, frag = target.partition("#")
            if not target:  # same-file anchor
                if frag and frag not in _anchors(md):
                    errors.append(f"{md.relative_to(root)}: broken anchor "
                                  f"#{frag}")
                continue
            dest = (md.parent / target).resolve()
            if not dest.exists():
                errors.append(f"{md.relative_to(root)}: missing target "
                              f"{target}")
                continue
            if frag and dest.suffix == ".md" and frag not in _anchors(dest):
                errors.append(f"{md.relative_to(root)}: {target}#{frag} — "
                              f"no such anchor in target")
    return errors


def main() -> int:
    root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()
    errors = check(root)
    for e in errors:
        print(f"BROKEN: {e}", file=sys.stderr)
    n = len(list(root.rglob("*.md")))
    print(f"checked {n} markdown files under {root}: "
          f"{len(errors)} broken reference(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
